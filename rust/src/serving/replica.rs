//! One replica: an OS thread that owns one engine and interleaves many
//! in-flight generations over it.
//!
//! PJRT handles are not `Send`, so the engine is constructed *on* this
//! thread and never leaves it; the replica is therefore the sharding
//! unit of the pool. Inside the thread, scheduling is iteration-level:
//! the loop alternates between admitting queued jobs (under the
//! [`Admission`] KV-byte budget) and advancing exactly one generation
//! by one quantum, as chosen by the [`StepScheduler`]. Cancellation and
//! deadlines are checked at every admission and before every quantum,
//! so a canceled long generation stops within one step.
//!
//! **Fault isolation (see `docs/RELIABILITY.md`):** every engine call
//! (`begin` / `step` / `step_batch` / `finish`) runs under
//! [`std::panic::catch_unwind`]. An ordinary `Err` stays what it always
//! was — an attributed per-request failure. A *panic* additionally
//! poisons the engine: the loop stops dispatching into it, strands every
//! in-flight generation uniformly (redirecting the ones that never
//! streamed a token to a healthy peer, bounded by
//! [`PoolConfig::max_request_retries`]), and returns
//! [`ReplicaExit::Poisoned`] so the supervisor in `serving/mod.rs` can
//! rebuild the engine. A failed *fused* decode dispatch is quarantined
//! instead: members are re-stepped individually so only the poison
//! generation fails and innocent batchmates keep streaming.

use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::Ordering;
use std::sync::Arc;
use std::time::{Duration, Instant};

use anyhow::Result;

use crate::coordinator::{GenRequest, SchedulerQueue};
use crate::kvcache::PrefixCache;
use crate::metrics::{labeled, occupancy_bucket, Registry, OCCUPANCY_BUCKETS};
use crate::model::{GenerateResult, Generation, ModelEngine, RequestInput, StepEvent};
use crate::streaming::EventSink;
use crate::trace::{
    collect_segs, Outcome, ReqTrace, Seg, TraceRecorder, TraceStats, TRACK_REQUEST,
};

use super::admission::{Admission, Admit, PrefixCharge};
use super::step_scheduler::StepScheduler;
use super::{lock_clean, PoolConfig, PoolShared, ReplicaHealth, ReplicaShared, Terminal};

/// The engine surface a replica drives. [`ModelEngine`] is the real
/// implementation; tests swap in a mock so the pool's scheduling and
/// conservation properties run without AOT artifacts.
pub trait ReplicaEngine {
    type Gen;

    /// Start a generation (embed + fused front + global pruning — or a
    /// mid-sequence resume from the shared prefix cache on a hit).
    fn begin(&mut self, req: &GenRequest) -> Result<Self::Gen>;

    /// Advance one quantum (one prefill layer or one decode step).
    fn step(&mut self, gen: &mut Self::Gen) -> Result<StepEvent>;

    /// Whether `gen` is decode-ready (prefill complete, not done) — the
    /// eligibility test for fused decode batching. The default `false`
    /// keeps engines without a batched kernel on the single-step path.
    fn is_decoding(&self, _gen: &Self::Gen) -> bool {
        false
    }

    /// Largest number of decode-ready generations [`Self::step_batch`]
    /// can advance in one fused dispatch (1 = no batching).
    fn max_decode_batch(&self) -> usize {
        1
    }

    /// Advance several decode-ready generations one token each in a
    /// single fused dispatch, returning one event per generation in
    /// order. Default: sequential single steps.
    ///
    /// **Contract: the dispatch is transactional.** On `Err`, no
    /// generation in the batch may have advanced — the pool's
    /// poison-batch quarantine re-steps members individually after a
    /// batch error, which would double-step any member the failed
    /// dispatch had already moved. (The fused `ModelEngine` path
    /// validates and uploads the whole batch before any KV append; the
    /// sequential default is only reachable with `max_decode_batch() ==
    /// 1`, where quarantine never engages.)
    fn step_batch(&mut self, gens: &mut [&mut Self::Gen]) -> Result<Vec<StepEvent>> {
        let mut out = Vec::with_capacity(gens.len());
        for g in gens.iter_mut() {
            out.push(self.step(g)?);
        }
        Ok(out)
    }

    /// Whether the generation has emitted its final token.
    fn is_done(&self, gen: &Self::Gen) -> bool;

    /// Consume the generation into its result (partial on abort).
    fn finish(&mut self, gen: Self::Gen) -> GenerateResult;

    /// Current KV bytes pinned by this generation.
    fn kv_bytes(&self, gen: &Self::Gen) -> usize;

    /// Conservative pre-admission KV-byte estimate for a request.
    fn estimate_bytes(&self, req: &GenRequest) -> usize;

    /// Hook: the pool hands every engine the process-wide prefix cache
    /// at startup. Engines that can reuse AV prefixes store it; the
    /// default ignores it.
    fn attach_prefix_cache(&mut self, _cache: Arc<PrefixCache>, _replica: usize) {}

    /// The shareable (already-resident) portion of `estimate_bytes`, as
    /// a refcounted charge so admission counts shared prefix blocks once
    /// across concurrent borrowers. `None` = everything is unique.
    fn prefix_probe(&self, _req: &GenRequest) -> Option<PrefixCharge> {
        None
    }

    /// Whether `gen` resumed from a cached AV prefix (observability
    /// only: names the trace's startup span `prefix_resume` vs `begin`).
    fn prefix_hit(&self, _gen: &Self::Gen) -> bool {
        false
    }

    /// Hook: enable/disable pipelined quantum execution (upload of layer
    /// `l+1` overlapped with the in-flight dispatch of layer `l`). The
    /// pool forwards [`PoolConfig::pipeline`] at startup; engines
    /// without a pipelined path ignore it.
    fn set_pipeline(&mut self, _on: bool) {}

    /// Eagerly release the generation's KV blocks at a terminal
    /// (finish/cancel/expire), in the same quantum the request retires —
    /// before result assembly and independent of whether the client has
    /// drained its stream. Must preserve whatever accounting `finish`
    /// still reads (peak bytes, pruning trace). Default: no-op for
    /// engines without real KV.
    fn release_kv(&mut self, _gen: &mut Self::Gen) {}
}

impl ReplicaEngine for ModelEngine {
    type Gen = Generation;

    fn begin(&mut self, req: &GenRequest) -> Result<Generation> {
        let input = RequestInput {
            prompt: &req.prompt,
            segments: &req.segments,
            frame_of: &req.frame_of,
        };
        // Per-request plan resolution: the spec that traveled with the
        // request becomes this generation's engine plan here, at the
        // engine boundary — there is no engine-global plan.
        self.begin_generation(&input, &req.options())
    }

    fn step(&mut self, gen: &mut Generation) -> Result<StepEvent> {
        self.step_generation(gen)
    }

    fn is_decoding(&self, gen: &Generation) -> bool {
        gen.is_decoding()
    }

    fn max_decode_batch(&self) -> usize {
        ModelEngine::max_decode_batch(self)
    }

    fn step_batch(&mut self, gens: &mut [&mut Generation]) -> Result<Vec<StepEvent>> {
        self.step_decode_batch(gens)
    }

    fn is_done(&self, gen: &Generation) -> bool {
        gen.is_done()
    }

    fn finish(&mut self, gen: Generation) -> GenerateResult {
        self.finish_generation(gen)
    }

    fn kv_bytes(&self, gen: &Generation) -> usize {
        gen.kv_bytes()
    }

    fn estimate_bytes(&self, req: &GenRequest) -> usize {
        // Admission charges the spec's *effective keep budget*: for a
        // query-independent global stage the post-prune live set is
        // computable host-side, so an aggressive profile reserves far
        // fewer KV bytes than a quality one on the same pool.
        self.estimate_kv_bytes_planned(
            req.spec.plan(),
            &req.segments,
            &req.frame_of,
            req.max_gen,
        )
    }

    fn attach_prefix_cache(&mut self, cache: Arc<PrefixCache>, _replica: usize) {
        self.set_prefix_cache(cache);
    }

    fn prefix_probe(&self, req: &GenRequest) -> Option<PrefixCharge> {
        self.prefix_shared_estimate(&req.prompt, &req.segments, &req.frame_of, req.spec.plan())
            .map(|(key, bytes)| PrefixCharge { key, bytes })
    }

    fn prefix_hit(&self, gen: &Generation) -> bool {
        gen.prefix_hit()
    }

    fn set_pipeline(&mut self, on: bool) {
        ModelEngine::set_pipeline(self, on);
    }

    fn release_kv(&mut self, gen: &mut Generation) {
        gen.release_kv();
    }
}

/// Why `replica_loop` returned: a clean drain (queue closed and empty,
/// nothing in flight) or an engine poisoning that needs a rebuild.
pub(crate) enum ReplicaExit {
    /// Queue closed + drained; the thread can exit.
    Drained,
    /// A caught engine panic poisoned the engine. Every in-flight
    /// request has been stranded (redirected or failed); the supervisor
    /// should rebuild the engine and re-enter the loop.
    Poisoned(String),
}

/// What a guarded engine call produced when it did not succeed.
pub(crate) enum EngineFault {
    /// The engine returned an ordinary error: attributed to the
    /// request(s), engine still usable.
    Err(anyhow::Error),
    /// The engine panicked: the panic was caught, the engine is
    /// poisoned, and the payload (if stringy) is preserved.
    Panic(String),
}

impl EngineFault {
    fn message(&self) -> String {
        match self {
            EngineFault::Err(e) => format!("{:#}", e),
            EngineFault::Panic(p) => format!("engine panicked: {}", p),
        }
    }
}

/// Run one engine call under `catch_unwind`, folding panic and `Err`
/// into [`EngineFault`]. `AssertUnwindSafe` is justified: after a panic
/// the caller poisons the engine and never dispatches into it again, so
/// broken interior state is unobservable.
fn guard<R>(f: impl FnOnce() -> Result<R>) -> std::result::Result<R, EngineFault> {
    match catch_unwind(AssertUnwindSafe(f)) {
        Ok(Ok(r)) => Ok(r),
        Ok(Err(e)) => Err(EngineFault::Err(e)),
        Err(p) => Err(EngineFault::Panic(panic_msg(p))),
    }
}

/// Best-effort human-readable panic payload.
fn panic_msg(p: Box<dyn std::any::Any + Send>) -> String {
    if let Some(s) = p.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = p.downcast_ref::<String>() {
        s.clone()
    } else {
        "non-string panic payload".to_string()
    }
}

/// A queued request (pool-internal).
pub(crate) struct Job {
    pub id: u64,
    pub req: GenRequest,
    pub enqueued: Instant,
    pub deadline: Option<Instant>,
    pub cancel: Arc<std::sync::atomic::AtomicBool>,
    /// Where tokens and the terminal event go: the buffered channel or a
    /// bounded per-request stream ([`crate::streaming::EventSink`]).
    pub events: EventSink,
    /// Times this request has been re-enqueued after a replica
    /// poisoning; bounded by [`PoolConfig::max_request_retries`].
    pub retries: u32,
    /// Sampled lifecycle trace (None on the untraced path — which is
    /// every request when `--trace-sample 0`).
    pub trace: Option<Box<ReqTrace>>,
}

/// One admitted, in-flight generation.
struct Active<G> {
    id: u64,
    gen: G,
    /// The original request, kept so a stranded generation that never
    /// streamed a token can be rebuilt into a [`Job`] and redirected to
    /// a healthy replica.
    req: GenRequest,
    cancel: Arc<std::sync::atomic::AtomicBool>,
    deadline: Option<Instant>,
    events: EventSink,
    /// Submission time — end-to-end `fastav_generate_seconds` and TTFT
    /// measure from here (SLO semantics: queue time counts).
    enqueued: Instant,
    /// Unique (non-shared) bytes reserved with the admission controller.
    est_bytes: usize,
    /// Shared-prefix charge reserved alongside (refcounted; see
    /// [`Admission::release_prefixed`]).
    prefix_charge: Option<PrefixCharge>,
    /// Decode-batch compatibility class of the request's pruning spec
    /// ([`crate::policy::PruningSpec::decode_class`]); fused quanta only
    /// mix entries of one class.
    spec_class: u64,
    /// Policy profile label for the per-profile latency histogram.
    profile: Option<String>,
    /// Whether the first token was already streamed (TTFT fires once;
    /// also the retry gate — a partially streamed generation is never
    /// re-run, it would duplicate tokens client-side).
    got_first_token: bool,
    /// Retry count carried over from the job.
    retries: u32,
    /// Whether this streaming request is currently parked on a slow
    /// consumer (its channel was full at quantum start): it skips decode
    /// quanta until the client drains, with its admission-held KV still
    /// charged. Always false for buffered requests.
    parked: bool,
    trace: Option<Box<ReqTrace>>,
}

/// Pre-resolved metric handles for one replica thread.
struct ReplicaMetrics {
    active_g: Arc<crate::metrics::Gauge>,
    kv_g: Arc<crate::metrics::Gauge>,
    sps_g: Arc<crate::metrics::Gauge>,
    steps_c: Arc<crate::metrics::Counter>,
    queue_hist: Arc<crate::metrics::Histogram>,
    gen_hist: Arc<crate::metrics::Histogram>,
    ttft_hist: Arc<crate::metrics::Histogram>,
    prefill_hist: Arc<crate::metrics::Histogram>,
    tok_hist: Arc<crate::metrics::Histogram>,
    completed_c: Arc<crate::metrics::Counter>,
    failed_c: Arc<crate::metrics::Counter>,
    canceled_c: Arc<crate::metrics::Counter>,
    expired_c: Arc<crate::metrics::Counter>,
    tokens_c: Arc<crate::metrics::Counter>,
    prefix_tokens_c: Arc<crate::metrics::Counter>,
    kv_peak: Arc<crate::metrics::Gauge>,
    /// Decode-batch occupancy distribution, one counter per
    /// [`OCCUPANCY_BUCKETS`] size class (histogram-style gauges).
    occ: Vec<Arc<crate::metrics::Counter>>,
    batched_steps_c: Arc<crate::metrics::Counter>,
    batched_tokens_c: Arc<crate::metrics::Counter>,
    /// Engine panics caught by quantum isolation.
    panics_c: Arc<crate::metrics::Counter>,
    /// Requests re-enqueued to a peer after a poisoning.
    retried_c: Arc<crate::metrics::Counter>,
    /// Requests failed individually by the poison-batch quarantine.
    quarantined_c: Arc<crate::metrics::Counter>,
    /// Token sends that found the client receiver gone.
    disconnects_c: Arc<crate::metrics::Counter>,
    /// Park transitions: a streaming request whose consumer stopped
    /// draining began skipping decode quanta.
    streams_parked_c: Arc<crate::metrics::Counter>,
    /// Tokens delivered into per-request streams (buffered sends are
    /// counted by `fastav_tokens_generated_total` alone).
    stream_tokens_c: Arc<crate::metrics::Counter>,
    /// Registry handle for per-profile labeled series resolved at
    /// terminal time (`fastav_stream_duration_seconds{profile=...}`).
    registry: Arc<Registry>,
    /// Per-shard mesh dispatch wall time (from trace "dispatch" segs).
    dispatch_hist: Arc<crate::metrics::Histogram>,
    /// Total KV upload (gather + literal build) nanoseconds.
    upload_ns_c: Arc<crate::metrics::Counter>,
    /// The subset of `upload_ns_c` that ran under an in-flight dispatch.
    upload_hidden_ns_c: Arc<crate::metrics::Counter>,
    /// hidden/total upload time, in permille (gauges are integers).
    overlap_g: Arc<crate::metrics::Gauge>,
}

impl ReplicaMetrics {
    fn new(metrics: &Arc<Registry>, replica: usize) -> ReplicaMetrics {
        let l = replica.to_string();
        ReplicaMetrics {
            active_g: metrics.gauge(&labeled("fastav_replica_active_requests", "replica", &l)),
            kv_g: metrics.gauge(&labeled("fastav_replica_kv_bytes", "replica", &l)),
            sps_g: metrics.gauge(&labeled("fastav_replica_steps_per_second", "replica", &l)),
            steps_c: metrics.counter(&labeled("fastav_replica_steps_total", "replica", &l)),
            queue_hist: metrics.histogram("fastav_queue_seconds"),
            gen_hist: metrics.histogram("fastav_generate_seconds"),
            ttft_hist: metrics.histogram("fastav_ttft_seconds"),
            prefill_hist: metrics.histogram("fastav_prefill_seconds"),
            tok_hist: metrics.histogram("fastav_decode_token_seconds"),
            completed_c: metrics.counter("fastav_requests_completed_total"),
            failed_c: metrics.counter("fastav_requests_failed_total"),
            canceled_c: metrics.counter("fastav_requests_canceled_total"),
            expired_c: metrics.counter("fastav_requests_expired_total"),
            tokens_c: metrics.counter("fastav_tokens_generated_total"),
            prefix_tokens_c: metrics.counter("fastav_prefix_tokens_reused_total"),
            kv_peak: metrics.gauge("fastav_kv_peak_bytes"),
            occ: OCCUPANCY_BUCKETS
                .iter()
                .map(|sz| metrics.counter(&labeled("fastav_decode_batch_occupancy", "size", sz)))
                .collect(),
            batched_steps_c: metrics.counter("fastav_decode_batched_steps_total"),
            batched_tokens_c: metrics.counter("fastav_decode_batched_tokens_total"),
            panics_c: metrics.counter("fastav_replica_panics_total"),
            retried_c: metrics.counter("fastav_requests_retried_total"),
            quarantined_c: metrics.counter("fastav_requests_quarantined_total"),
            disconnects_c: metrics.counter("fastav_client_disconnects_total"),
            streams_parked_c: metrics.counter("fastav_streams_parked_total"),
            stream_tokens_c: metrics.counter("fastav_stream_tokens_sent_total"),
            registry: Arc::clone(metrics),
            dispatch_hist: metrics.histogram("fastav_mesh_dispatch_seconds"),
            upload_ns_c: metrics.counter("fastav_upload_ns_total"),
            upload_hidden_ns_c: metrics.counter("fastav_upload_hidden_ns_total"),
            overlap_g: metrics.gauge("fastav_upload_overlap_ratio"),
        }
    }
}

/// Fold a quantum's trace segments into the mesh pipeline metrics:
/// each "dispatch" segment lands in the dispatch-seconds histogram, and
/// "upload" segments accumulate total vs dispatch-hidden nanoseconds,
/// from which the overlap-ratio gauge (permille) is recomputed.
///
/// Segments exist only for traced quanta (sampling per `trace_sample`),
/// so these metrics are a sample of the pipeline, not a census — the
/// ratio is unbiased because sampling is per-request, not per-segment.
fn note_mesh_segs(m: &ReplicaMetrics, segs: &[crate::trace::Seg]) {
    for sg in segs {
        let dur = sg.end_ns.saturating_sub(sg.start_ns);
        match sg.name {
            "dispatch" => m.dispatch_hist.observe(dur as f64 / 1e9),
            "upload" => {
                m.upload_ns_c.add(dur);
                if sg.overlap {
                    m.upload_hidden_ns_c.add(dur);
                }
                let total = m.upload_ns_c.get();
                if total > 0 {
                    m.overlap_g.set(m.upload_hidden_ns_c.get() * 1000 / total);
                }
            }
            _ => {}
        }
    }
}

/// Count one caught engine panic (replica counter + pool metric).
fn note_panic(m: &ReplicaMetrics, rshared: &ReplicaShared) {
    m.panics_c.inc();
    rshared.panics.fetch_add(1, Ordering::SeqCst);
}

/// What to do with one in-flight entry after a quantum.
enum RetireAction {
    /// The generation emitted its final token: finish + Done event.
    Complete,
    /// Fail with this attributed message.
    Fail(String),
}

/// The replica thread body: admit → step → account, until the queue is
/// closed and drained and no generation is in flight
/// ([`ReplicaExit::Drained`]) or the engine is poisoned by a caught
/// panic ([`ReplicaExit::Poisoned`]).
#[allow(clippy::too_many_arguments)]
pub(crate) fn replica_loop<E: ReplicaEngine>(
    replica_id: usize,
    mut engine: E,
    cfg: &PoolConfig,
    queue: &SchedulerQueue<Job>,
    rshared: &ReplicaShared,
    pshared: &PoolShared,
    metrics: &Arc<Registry>,
    prefix: Option<Arc<PrefixCache>>,
    tracer: &Arc<TraceRecorder>,
) -> ReplicaExit {
    let m = ReplicaMetrics::new(metrics, replica_id);
    if let Some(c) = prefix.clone() {
        engine.attach_prefix_cache(c, replica_id);
    }
    engine.set_pipeline(cfg.pipeline);
    // A replica is a device group: admission charges KV bytes against
    // the group's pooled capacity (per-device budget × tp_degree).
    let mut admission = Admission::new(cfg.group_kv_budget_bytes(), cfg.max_inflight);
    let mut sched = StepScheduler::new();
    let mut active: Vec<Active<E::Gen>> = Vec::new();
    let mut parked: Option<Job> = None;
    let mut rate_steps = 0u64;
    let mut rate_t0 = Instant::now();
    // Set the moment a caught panic poisons the engine; once set, the
    // loop stops dispatching and falls through to `strand_all`.
    let mut poison: Option<String> = None;

    'outer: loop {
        // ---- Admission: pull queued jobs into the step scheduler. ----
        while poison.is_none() && admission.has_slot() {
            // A parked (budget-deferred) job is already counted as
            // in-flight; fresh pops are counted on arrival.
            let mut counted = false;
            let job = if let Some(j) = parked.take() {
                counted = true;
                Some(j)
            } else if active.is_empty() {
                match queue.pop_blocking() {
                    Some(j) => Some(j),
                    None => return ReplicaExit::Drained, // closed + drained, nothing running
                }
            } else {
                queue.try_pop_fair()
            };
            let Some(mut job) = job else { break };
            if !counted {
                rshared.active.fetch_add(1, Ordering::SeqCst);
            }
            // A freshly popped trace has its `queue` span open — close
            // it now. On a parked retry the stack is already back at
            // the root and `end()` is a no-op.
            if let Some(t) = job.trace.as_mut() {
                t.end();
            }
            if job.cancel.load(Ordering::SeqCst) {
                commit_job_trace(tracer, replica_id, &mut job, Outcome::Canceled);
                settle_job(&job, Terminal::Canceled, "canceled before start", rshared, pshared, &m);
                continue;
            }
            if job.deadline.is_some_and(|d| Instant::now() >= d) {
                commit_job_trace(tracer, replica_id, &mut job, Outcome::Expired);
                settle_job(&job, Terminal::Expired, "deadline exceeded in queue", rshared, pshared, &m);
                continue;
            }
            if let Some(t) = job.trace.as_mut() {
                t.begin("admit");
            }
            let est = engine.estimate_bytes(&job.req);
            // Split the estimate: bytes the request will borrow from a
            // resident prefix entry are charged once across borrowers.
            let probe_t0 = job.trace.as_ref().map(|t| t.now_ns());
            let charge = engine.prefix_probe(&job.req);
            if let Some(t) = job.trace.as_mut() {
                let now = t.now_ns();
                let s = t.record("prefix_probe", TRACK_REQUEST, probe_t0.unwrap_or(now), now);
                if let Some(c) = &charge {
                    t.attr_u64_on(s, "shared_bytes", c.bytes as u64);
                }
            }
            let verdict = admission.check_prefixed(unique_of(est, &charge), charge);
            if let Some(t) = job.trace.as_mut() {
                t.attr_str("outcome", verdict.name());
                t.end();
            }
            match verdict {
                Admit::Granted => {}
                Admit::Defer => {
                    // Re-examined once a running generation releases
                    // budget; stays counted as in-flight meanwhile.
                    parked = Some(job);
                    break;
                }
                Admit::Oversize => {
                    commit_job_trace(tracer, replica_id, &mut job, Outcome::Failed);
                    settle_job(
                        &job,
                        Terminal::Failed,
                        &format!(
                            "request needs ~{} KV bytes, over the replica budget {}",
                            est,
                            admission.budget_bytes()
                        ),
                        rshared,
                        pshared,
                        &m,
                    );
                    continue;
                }
            }
            let unique = unique_of(est, &charge);
            m.queue_hist.observe(job.enqueued.elapsed().as_secs_f64());
            let spec_class = job.req.spec.decode_class();
            // `begin` is one quantum-sized unit of engine work (embed +
            // fused front + global prune, or a prefix-cache resume);
            // traced requests time it and collect the engine's internal
            // segments (prefix lookups, mesh upload/dispatch/download).
            let begin_t0 = job.trace.as_ref().map(|t| t.now_ns());
            let (begun, begin_segs) = if job.trace.is_some() {
                collect_segs(tracer.clock(), || guard(|| engine.begin(&job.req)))
            } else {
                (guard(|| engine.begin(&job.req)), Vec::new())
            };
            match begun {
                Ok(gen) => {
                    if let Some(t) = job.trace.as_mut() {
                        let name =
                            if engine.prefix_hit(&gen) { "prefix_resume" } else { "begin" };
                        let now = t.now_ns();
                        let s = t.record(name, TRACK_REQUEST, begin_t0.unwrap_or(now), now);
                        t.attr_u64_on(s, "prompt_tokens", job.req.prompt.len() as u64);
                        record_segs(t, s, &begin_segs);
                    }
                    sched.admit_with_affinity(
                        job.id,
                        job.req.priority,
                        job.deadline,
                        charge.map(|c| c.key),
                    );
                    active.push(Active {
                        id: job.id,
                        gen,
                        cancel: job.cancel,
                        deadline: job.deadline,
                        events: job.events,
                        enqueued: job.enqueued,
                        est_bytes: unique,
                        prefix_charge: charge,
                        spec_class,
                        profile: job.req.profile.clone(),
                        got_first_token: false,
                        retries: job.retries,
                        parked: false,
                        req: job.req,
                        trace: job.trace,
                    });
                }
                Err(EngineFault::Err(e)) => {
                    if let Some(t) = job.trace.as_mut() {
                        let now = t.now_ns();
                        t.record("begin", TRACK_REQUEST, begin_t0.unwrap_or(now), now);
                    }
                    admission.release_prefixed(unique, charge);
                    commit_job_trace(tracer, replica_id, &mut job, Outcome::Failed);
                    settle_job(&job, Terminal::Failed, &format!("{:#}", e), rshared, pshared, &m);
                }
                Err(EngineFault::Panic(p)) => {
                    // The job itself never began — it is redirectable.
                    // Park it so `strand_all` treats it like every other
                    // stranded request, and poison the engine.
                    if let Some(t) = job.trace.as_mut() {
                        let now = t.now_ns();
                        t.record("begin", TRACK_REQUEST, begin_t0.unwrap_or(now), now);
                    }
                    admission.release_prefixed(unique, charge);
                    note_panic(&m, rshared);
                    poison = Some(format!(
                        "replica {}: engine panicked during begin: {}",
                        replica_id, p
                    ));
                    parked = Some(job);
                    break;
                }
            }
        }
        m.active_g.set(active.len() as u64);
        if poison.is_some() {
            break 'outer;
        }
        if active.is_empty() {
            continue; // back to the blocking pop (or retry the parked job)
        }

        // ---- Cancellation/deadline sweep over every in-flight entry
        // (a batched quantum advances many at once, so all must be
        // checked, not just one pick). ----
        let now = Instant::now();
        let mut i = 0;
        while i < active.len() {
            let kind = if active[i].cancel.load(Ordering::SeqCst) {
                Some((Terminal::Canceled, "canceled"))
            } else if active[i].deadline.is_some_and(|d| now >= d) {
                Some((Terminal::Expired, "deadline exceeded"))
            } else {
                None
            };
            match kind {
                Some((kind, msg)) => {
                    if let Some(p) = retire_at(
                        &mut engine, &mut active, &mut sched, i, kind, msg,
                        &mut admission, rshared, pshared, &m, tracer, replica_id, true,
                    ) {
                        poison = Some(p);
                        break;
                    }
                }
                None => i += 1,
            }
        }
        m.active_g.set(active.len() as u64);
        if poison.is_some() {
            break 'outer;
        }
        if active.is_empty() {
            continue;
        }

        // ---- One scheduling quantum: a chunked-prefill step for one
        // entry, or one fused decode batch over the decode-ready set
        // (quantum model: prefill = 1 chunk, decode = 1 batch). ----
        let max_b = match cfg.max_decode_batch {
            0 => engine.max_decode_batch(),
            n => n.min(engine.max_decode_batch()),
        };
        let ready: Vec<bool> = active.iter().map(|a| engine.is_decoding(&a.gen)).collect();
        let classes: Vec<u64> = active.iter().map(|a| a.spec_class).collect();

        // ---- Park/unpark sweep: a streaming consumer whose token
        // channel is full is *parked* — it keeps its admission-charged
        // KV but is excluded from this quantum entirely (never primary,
        // never a batchmate), so one slow client cannot stall the
        // quantum or perturb fused batchmates. Buffered sinks are
        // always ready and never park. ----
        let mut blocked: Vec<bool> = Vec::with_capacity(active.len());
        for a in active.iter_mut() {
            let block = !a.events.ready();
            if block && !a.parked {
                a.parked = true;
                m.streams_parked_c.inc();
                pshared.streams_parked.fetch_add(1, Ordering::Relaxed);
                if let Some(t) = a.trace.as_mut() {
                    let now = t.now_ns();
                    t.record("stream_park", TRACK_REQUEST, now, now);
                }
            } else if !block && a.parked {
                a.parked = false;
                pshared.streams_parked.fetch_sub(1, Ordering::Relaxed);
                if let Some(t) = a.trace.as_mut() {
                    let now = t.now_ns();
                    t.record("stream_resume", TRACK_REQUEST, now, now);
                }
            }
            blocked.push(block);
        }

        let picked = sched.pick_batch_gated(max_b, &ready, &classes, &blocked);
        if picked.is_empty() {
            // Everything runnable is parked behind slow consumers.
            // Sleep briefly instead of busy-spinning so the drain (a
            // client read on another thread) can make progress, then
            // re-run the admission/cancel/park sweeps.
            std::thread::sleep(Duration::from_micros(200));
            continue;
        }
        let decode_quantum = ready[picked[0]];

        // Traced participants share one quantum timing: measure the
        // dispatch once on the recorder clock, collect the engine's
        // internal segments, and record a closed span into each traced
        // trace below. Untraced quanta skip all of it.
        let any_traced = picked.iter().any(|&i| active[i].trace.is_some());
        let q_t0 = if any_traced { Some(tracer.clock().now_ns()) } else { None };
        let (stepped, q_segs) = if any_traced {
            collect_segs(tracer.clock(), || {
                guard(|| step_picked(&mut engine, &mut active, &picked))
            })
        } else {
            (guard(|| step_picked(&mut engine, &mut active, &picked)), Vec::new())
        };
        let q_t1 = q_t0.map(|_| tracer.clock().now_ns());
        note_mesh_segs(&m, &q_segs);

        match stepped {
            Ok(events) => {
                debug_assert_eq!(events.len(), picked.len());
                if decode_quantum {
                    let b = picked.len();
                    m.occ[occupancy_bucket(b)].inc();
                    rshared.batch_quanta.fetch_add(1, Ordering::Relaxed);
                    rshared.batch_tokens.fetch_add(b as u64, Ordering::Relaxed);
                    if b >= 2 {
                        m.batched_steps_c.inc();
                        m.batched_tokens_c.add(b as u64);
                    }
                }
                let pairs: Vec<(usize, StepEvent)> =
                    picked.iter().copied().zip(events).collect();
                let finished = deliver(
                    &engine, &mut active, &pairs, decode_quantum, picked.len(),
                    sched.quantum_seq(), q_t0, q_t1, &q_segs, &m, rshared, &mut rate_steps,
                );
                let actions: Vec<(usize, RetireAction)> =
                    finished.into_iter().map(|i| (i, RetireAction::Complete)).collect();
                retire_set(
                    &mut engine, &mut active, &mut sched, actions, &mut admission,
                    rshared, pshared, &m, metrics, tracer, replica_id, &mut poison,
                );
            }
            Err(EngineFault::Err(e)) if decode_quantum && picked.len() >= 2 => {
                // Poison-batch quarantine: the fused dispatch is
                // transactional (no member advanced on Err — see the
                // `step_batch` contract), so re-step every member alone.
                // Only the poison generation(s) fail; innocent
                // batchmates keep token streams byte-identical to a
                // fault-free run.
                let batch_msg = format!("{:#}", e);
                let mut actions: Vec<(usize, RetireAction)> = Vec::new();
                let mut ok_pairs: Vec<(usize, StepEvent)> = Vec::new();
                for &idx in &picked {
                    if poison.is_some() {
                        actions.push((idx, RetireAction::Fail(format!(
                            "replica {} poisoned during quarantine of a failed batch ({})",
                            replica_id, batch_msg
                        ))));
                        continue;
                    }
                    match guard(|| engine.step(&mut active[idx].gen)) {
                        Ok(ev) => ok_pairs.push((idx, ev)),
                        Err(EngineFault::Err(e2)) => {
                            m.quarantined_c.inc();
                            actions.push((idx, RetireAction::Fail(format!("{:#}", e2))));
                        }
                        Err(EngineFault::Panic(p)) => {
                            note_panic(&m, rshared);
                            m.quarantined_c.inc();
                            let msg = format!(
                                "replica {}: engine panicked during quarantine retry: {}",
                                replica_id, p
                            );
                            actions.push((idx, RetireAction::Fail(msg.clone())));
                            poison = Some(msg);
                        }
                    }
                }
                let q_t1 = q_t0.map(|_| tracer.clock().now_ns());
                let finished = deliver(
                    &engine, &mut active, &ok_pairs, true, 1,
                    sched.quantum_seq(), q_t0, q_t1, &q_segs, &m, rshared, &mut rate_steps,
                );
                for i in finished {
                    actions.push((i, RetireAction::Complete));
                }
                retire_set(
                    &mut engine, &mut active, &mut sched, actions, &mut admission,
                    rshared, pshared, &m, metrics, tracer, replica_id, &mut poison,
                );
            }
            Err(EngineFault::Err(e)) => {
                // Single-generation quantum (or an engine without fused
                // batching): the error is attributed to the picked set
                // as a whole.
                let msg = format!("{:#}", e);
                let actions: Vec<(usize, RetireAction)> =
                    picked.iter().map(|&i| (i, RetireAction::Fail(msg.clone()))).collect();
                retire_set(
                    &mut engine, &mut active, &mut sched, actions, &mut admission,
                    rshared, pshared, &m, metrics, tracer, replica_id, &mut poison,
                );
            }
            Err(EngineFault::Panic(p)) => {
                // A panic mid-dispatch leaves the engine state
                // unknowable — do not retire the picked set here. Poison
                // the replica and let `strand_all` treat every in-flight
                // generation uniformly (the ones that never streamed a
                // token are redirected to a healthy peer).
                note_panic(&m, rshared);
                poison = Some(format!(
                    "replica {}: engine panicked during {}: {}",
                    replica_id,
                    if decode_quantum { "decode quantum" } else { "prefill chunk" },
                    p
                ));
            }
        }
        m.active_g.set(active.len() as u64);
        if poison.is_some() {
            break 'outer;
        }

        // ---- Gauges: KV footprint + steps/s. ----
        let kv_now: usize = active.iter().map(|a| engine.kv_bytes(&a.gen)).sum();
        rshared.kv_bytes.store(kv_now as u64, Ordering::Relaxed);
        m.kv_g.set(kv_now as u64);
        let dt = rate_t0.elapsed().as_secs_f64();
        if dt >= 0.5 {
            let sps = (rate_steps as f64 / dt).round() as u64;
            rshared.steps_per_sec.store(sps, Ordering::Relaxed);
            m.sps_g.set(sps);
            // Block-pool gauges drift with every append/compact, not only
            // with cache operations — refresh them on the rate tick.
            if let Some(c) = &prefix {
                c.refresh_gauges();
            }
            rate_steps = 0;
            rate_t0 = Instant::now();
        }
    }

    // Poisoned exit: the engine is unusable. Strand every in-flight
    // generation (and a parked job, if any) uniformly, then hand the
    // thread back to the supervisor for an engine rebuild.
    let msg = poison.unwrap_or_else(|| format!("replica {} poisoned", replica_id));
    strand_all(
        active, parked, &msg, cfg, &mut admission, rshared, pshared, &m, tracer, replica_id,
    );
    ReplicaExit::Poisoned(msg)
}

/// Advance the picked set by one quantum: a single step when the pick
/// is one generation, one fused decode dispatch otherwise.
fn step_picked<E: ReplicaEngine>(
    engine: &mut E,
    active: &mut [Active<E::Gen>],
    picked: &[usize],
) -> Result<Vec<StepEvent>> {
    if picked.len() == 1 {
        return engine.step(&mut active[picked[0]].gen).map(|ev| vec![ev]);
    }
    // Disjoint &mut borrows of the picked generations (ascending
    // indices) for one fused dispatch.
    let mut gens: Vec<&mut E::Gen> = Vec::with_capacity(picked.len());
    let mut want = picked.iter().copied().peekable();
    for (i, a) in active.iter_mut().enumerate() {
        if want.peek() == Some(&i) {
            want.next();
            gens.push(&mut a.gen);
        }
    }
    engine.step_batch(&mut gens)
}

/// Deliver one quantum's events to their requests: trace spans, token
/// sends (flipping the cancel flag on client disconnect), TTFT, and
/// step counters. Returns the indices whose generations finished.
#[allow(clippy::too_many_arguments)]
fn deliver<E: ReplicaEngine>(
    engine: &E,
    active: &mut [Active<E::Gen>],
    pairs: &[(usize, StepEvent)],
    decode_quantum: bool,
    batch: usize,
    seq: u64,
    q_t0: Option<u64>,
    q_t1: Option<u64>,
    q_segs: &[Seg],
    m: &ReplicaMetrics,
    rshared: &ReplicaShared,
    rate_steps: &mut u64,
) -> Vec<usize> {
    let mut finished: Vec<usize> = Vec::new();
    for (idx, ev) in pairs {
        let idx = *idx;
        let entry = &mut active[idx];
        if let (Some(t0), Some(t1)) = (q_t0, q_t1) {
            if let Some(t) = entry.trace.as_mut() {
                let s = if decode_quantum {
                    let s = t.record("decode_quantum", TRACK_REQUEST, t0, t1);
                    t.attr_u64_on(s, "batch", batch as u64);
                    t.attr_u64_on(s, "class", entry.spec_class);
                    s
                } else {
                    let s = t.record("prefill_chunk", TRACK_REQUEST, t0, t1);
                    if let StepEvent::Prefilled { layer } = ev {
                        t.attr_u64_on(s, "layer", *layer as u64);
                    }
                    s
                };
                t.attr_u64_on(s, "seq", seq);
                record_segs(t, s, q_segs);
            }
        }
        match ev {
            StepEvent::Token(t) => {
                // A failed send means the client receiver is gone: flip
                // the cancel flag so the disconnected request stops
                // consuming quanta within one step instead of running to
                // its deadline. `swap` counts each disconnect once.
                let is_stream = entry.events.is_stream();
                if entry.events.send_token(*t).is_err() {
                    if !entry.cancel.swap(true, Ordering::SeqCst) {
                        m.disconnects_c.inc();
                    }
                } else if is_stream {
                    m.stream_tokens_c.inc();
                }
                if !entry.got_first_token {
                    entry.got_first_token = true;
                    m.ttft_hist.observe(entry.enqueued.elapsed().as_secs_f64());
                    if let Some(tr) = entry.trace.as_mut() {
                        tr.mark_first_token();
                        if is_stream {
                            let now = tr.now_ns();
                            tr.record("first_token_sent", TRACK_REQUEST, now, now);
                        }
                    }
                }
                m.steps_c.inc();
                rshared.steps_total.fetch_add(1, Ordering::Relaxed);
                *rate_steps += 1;
                if engine.is_done(&entry.gen) {
                    finished.push(idx);
                }
            }
            StepEvent::Prefilled { .. } => {
                m.steps_c.inc();
                rshared.steps_total.fetch_add(1, Ordering::Relaxed);
                *rate_steps += 1;
            }
            StepEvent::Done => finished.push(idx),
        }
    }
    finished
}

/// Retire a set of entries (descending-index order so positions stay
/// valid): completions run the full result path through a *guarded*
/// `finish`; failures go through [`retire_at`]. A panic inside `finish`
/// sets `poison` — later entries in the same set are then settled
/// without touching the engine.
#[allow(clippy::too_many_arguments)]
fn retire_set<E: ReplicaEngine>(
    engine: &mut E,
    active: &mut Vec<Active<E::Gen>>,
    sched: &mut StepScheduler,
    mut actions: Vec<(usize, RetireAction)>,
    admission: &mut Admission,
    rshared: &ReplicaShared,
    pshared: &PoolShared,
    m: &ReplicaMetrics,
    metrics: &Registry,
    tracer: &TraceRecorder,
    replica_id: usize,
    poison: &mut Option<String>,
) {
    actions.sort_by(|a, b| b.0.cmp(&a.0));
    for (idx, action) in actions {
        match action {
            RetireAction::Complete => {
                let mut a = active.remove(idx);
                sched.remove(idx);
                if poison.is_some() {
                    // The engine died before this result could be
                    // assembled; the tokens streamed but the final
                    // GenerateResult is unrecoverable.
                    if let Some(t) = a.trace.take() {
                        tracer.commit(t, replica_id, Outcome::Failed, TraceStats::default());
                    }
                    settle_terminal(
                        Terminal::Failed,
                        &format!("replica {} poisoned before result assembly", replica_id),
                        &a.events, rshared, pshared, m, true,
                    );
                    close_stream(&a.events, a.profile.as_deref(), a.enqueued, a.parked, pshared, metrics);
                    admission.release_prefixed(a.est_bytes, a.prefix_charge);
                    lock_clean(&pshared.cancels).remove(&a.id);
                    continue;
                }
                // Eager terminal cleanup: drop the generation's
                // non-prefix-shared KV blocks in the *same quantum* the
                // terminal fires, before the result is assembled — a
                // slow (or parked) consumer draining the stream later
                // must not pin pool blocks.
                let mut gen = a.gen;
                match guard(|| {
                    engine.release_kv(&mut gen);
                    Ok(engine.finish(gen))
                }) {
                    Ok(res) => {
                        // End-to-end latency (submit → finish). For
                        // traced requests the histogram observes
                        // *exactly* the trace root's duration, so
                        // `/v1/trace/{id}` and `fastav_generate_seconds`
                        // can never disagree.
                        let gen_secs = match a.trace.take() {
                            Some(t) => tracer.commit(
                                t,
                                replica_id,
                                Outcome::Completed,
                                stats_of(&res),
                            ),
                            None => a.enqueued.elapsed().as_secs_f64(),
                        };
                        m.gen_hist.observe(gen_secs);
                        if let Some(p) = &a.profile {
                            metrics
                                .histogram(&labeled("fastav_generate_seconds", "profile", p))
                                .observe(gen_secs);
                        }
                        m.prefill_hist.observe(res.prefill_seconds);
                        if res.decode_steps > 0 {
                            m.tok_hist.observe(res.decode_seconds / res.decode_steps as f64);
                        }
                        m.kv_peak.max(res.peak_kv_bytes as u64);
                        m.tokens_c.add(res.tokens.len() as u64);
                        m.prefix_tokens_c.add(res.prefix_tokens_reused as u64);
                        m.completed_c.inc();
                        pshared.completed.fetch_add(1, Ordering::SeqCst);
                        rshared.completed.fetch_add(1, Ordering::SeqCst);
                        // The receiver may be gone (disconnect): the
                        // request is complete either way.
                        a.events.send_done(Box::new(res));
                        close_stream(&a.events, a.profile.as_deref(), a.enqueued, a.parked, pshared, metrics);
                        admission.release_prefixed(a.est_bytes, a.prefix_charge);
                        lock_clean(&pshared.cancels).remove(&a.id);
                        rshared.active.fetch_sub(1, Ordering::SeqCst);
                    }
                    Err(fault) => {
                        note_panic(m, rshared);
                        let msg = format!(
                            "replica {} poisoned at finish: {}",
                            replica_id,
                            fault.message()
                        );
                        if let Some(t) = a.trace.take() {
                            tracer.commit(t, replica_id, Outcome::Failed, TraceStats::default());
                        }
                        settle_terminal(Terminal::Failed, &msg, &a.events, rshared, pshared, m, true);
                        close_stream(&a.events, a.profile.as_deref(), a.enqueued, a.parked, pshared, metrics);
                        admission.release_prefixed(a.est_bytes, a.prefix_charge);
                        lock_clean(&pshared.cancels).remove(&a.id);
                        *poison = Some(msg);
                    }
                }
            }
            RetireAction::Fail(msg) => {
                let engine_ok = poison.is_none();
                if let Some(p) = retire_at(
                    engine, active, sched, idx, Terminal::Failed, &msg, admission,
                    rshared, pshared, m, tracer, replica_id, engine_ok,
                ) {
                    *poison = Some(p);
                }
            }
        }
    }
}

/// Retire in-flight entry `idx` into a terminal state: finish (guarded;
/// skipped entirely when `engine_ok` is false) and drop its partial
/// generation, settle counters/events, and release its admission charge.
/// Returns a poison message if `finish` itself panicked.
#[allow(clippy::too_many_arguments)]
fn retire_at<E: ReplicaEngine>(
    engine: &mut E,
    active: &mut Vec<Active<E::Gen>>,
    sched: &mut StepScheduler,
    idx: usize,
    kind: Terminal,
    msg: &str,
    admission: &mut Admission,
    rshared: &ReplicaShared,
    pshared: &PoolShared,
    m: &ReplicaMetrics,
    tracer: &TraceRecorder,
    replica_id: usize,
    engine_ok: bool,
) -> Option<String> {
    let mut a = active.remove(idx);
    sched.remove(idx);
    let mut poison = None;
    let stats = if engine_ok {
        // Eager terminal cleanup (cancel/expire/fail): release the
        // generation's non-prefix-shared KV in this quantum.
        let mut gen = a.gen;
        match guard(|| {
            engine.release_kv(&mut gen);
            Ok(engine.finish(gen))
        }) {
            Ok(res) => stats_of(&res),
            Err(fault) => {
                note_panic(m, rshared);
                poison = Some(format!(
                    "replica {} poisoned at finish: {}",
                    replica_id,
                    fault.message()
                ));
                TraceStats::default()
            }
        }
    } else {
        // Poisoned engine: drop the generation without an engine call.
        TraceStats::default()
    };
    if let Some(t) = a.trace.take() {
        let outcome = match kind {
            Terminal::Canceled => Outcome::Canceled,
            Terminal::Expired => Outcome::Expired,
            Terminal::Failed => Outcome::Failed,
        };
        tracer.commit(t, replica_id, outcome, stats);
    }
    settle_terminal(kind, msg, &a.events, rshared, pshared, m, true);
    close_stream(&a.events, a.profile.as_deref(), a.enqueued, a.parked, pshared, &m.registry);
    admission.release_prefixed(a.est_bytes, a.prefix_charge);
    lock_clean(&pshared.cancels).remove(&a.id);
    poison
}

/// Strand every in-flight generation (plus a parked job) after a
/// poisoning: requests that never streamed a token and still have retry
/// budget are rebuilt into jobs and pushed to the healthiest peer
/// (possibly this replica's own queue — it drains after the respawn);
/// everything else fails with the attributed engine error.
#[allow(clippy::too_many_arguments)]
fn strand_all<G>(
    active: Vec<Active<G>>,
    parked: Option<Job>,
    reason: &str,
    cfg: &PoolConfig,
    admission: &mut Admission,
    rshared: &ReplicaShared,
    pshared: &PoolShared,
    m: &ReplicaMetrics,
    tracer: &TraceRecorder,
    replica_id: usize,
) {
    if let Some(mut job) = parked {
        // A parked job is counted in-flight but never began — always
        // redirect-eligible while retry budget remains.
        if job.retries < cfg.max_request_retries {
            job.retries += 1;
            mark_redirect(&mut job.trace, true);
            match push_to_peer(job, replica_id, pshared) {
                Ok(()) => {
                    pshared.retried.fetch_add(1, Ordering::SeqCst);
                    m.retried_c.inc();
                    rshared.active.fetch_sub(1, Ordering::SeqCst);
                }
                Err(mut j) => {
                    commit_job_trace(tracer, replica_id, &mut j, Outcome::Failed);
                    settle_job(
                        &j,
                        Terminal::Failed,
                        &format!("{} (no replica accepted the retry)", reason),
                        rshared, pshared, m,
                    );
                }
            }
        } else {
            commit_job_trace(tracer, replica_id, &mut job, Outcome::Failed);
            settle_job(
                &job,
                Terminal::Failed,
                &format!("{} (retry budget exhausted)", reason),
                rshared, pshared, m,
            );
        }
    }
    for mut a in active {
        admission.release_prefixed(a.est_bytes, a.prefix_charge);
        let retryable = !a.got_first_token && a.retries < cfg.max_request_retries;
        if retryable {
            // A parked entry has streamed tokens, so it can never be
            // retryable — but keep the pool-wide parked count exact even
            // if that invariant ever shifts.
            if a.parked {
                pshared.streams_parked.fetch_sub(1, Ordering::Relaxed);
            }
            let mut job = Job {
                id: a.id,
                req: a.req,
                enqueued: a.enqueued,
                deadline: a.deadline,
                cancel: a.cancel,
                events: a.events,
                retries: a.retries + 1,
                trace: a.trace,
            };
            mark_redirect(&mut job.trace, true);
            match push_to_peer(job, replica_id, pshared) {
                Ok(()) => {
                    pshared.retried.fetch_add(1, Ordering::SeqCst);
                    m.retried_c.inc();
                    rshared.active.fetch_sub(1, Ordering::SeqCst);
                }
                Err(mut j) => {
                    commit_job_trace(tracer, replica_id, &mut j, Outcome::Failed);
                    settle_job(
                        &j,
                        Terminal::Failed,
                        &format!("{} (no replica accepted the retry)", reason),
                        rshared, pshared, m,
                    );
                }
            }
        } else {
            let why = if a.got_first_token {
                format!("{} (generation already streamed tokens; not retryable)", reason)
            } else {
                format!("{} (retry budget exhausted)", reason)
            };
            if let Some(t) = a.trace.take() {
                tracer.commit(t, replica_id, Outcome::Failed, TraceStats::default());
            }
            settle_terminal(Terminal::Failed, &why, &a.events, rshared, pshared, m, true);
            close_stream(&a.events, a.profile.as_deref(), a.enqueued, a.parked, pshared, &m.registry);
            lock_clean(&pshared.cancels).remove(&a.id);
        }
    }
    m.active_g.set(0);
    m.kv_g.set(0);
    rshared.kv_bytes.store(0, Ordering::Relaxed);
}

/// Settle a job popped from a dying replica's queue (`go_dead` in
/// `serving/mod.rs`): redirect it to a peer while retry budget remains,
/// otherwise fail it with the attributed reason. Queued jobs were never
/// counted in `rshared.active`, so no in-flight accounting moves here —
/// a redirected job re-enters a peer's `in_queue`, a failed one counts
/// terminal.
pub(crate) fn strand_queued_job(
    mut job: Job,
    from: usize,
    reason: &str,
    cfg: &PoolConfig,
    pshared: &PoolShared,
    metrics: &Registry,
    tracer: &TraceRecorder,
) {
    if job.retries < cfg.max_request_retries {
        job.retries += 1;
        // The queue span is still open (the job was never popped by a
        // replica loop) — record the redirect and keep it open for the
        // peer to close at pop.
        mark_redirect(&mut job.trace, false);
        match push_to_peer(job, from, pshared) {
            Ok(()) => {
                pshared.retried.fetch_add(1, Ordering::SeqCst);
                metrics.counter("fastav_requests_retried_total").inc();
                return;
            }
            Err(j) => job = j,
        }
    }
    if let Some(t) = job.trace.as_mut() {
        t.end(); // close the still-open queue span
    }
    commit_job_trace(tracer, from, &mut job, Outcome::Failed);
    metrics.counter("fastav_requests_failed_total").inc();
    pshared.failed.fetch_add(1, Ordering::SeqCst);
    job.events.send_error(reason.to_string());
    close_stream(&job.events, job.req.profile.as_deref(), job.enqueued, false, pshared, metrics);
    lock_clean(&pshared.cancels).remove(&job.id);
}

/// Push a stranded job to the best peer replica: healthy first, this
/// replica's own queue last (it only drains after a successful respawn),
/// least-loaded within each tier. Dead replicas' queues are closed and
/// reject the push naturally. Lock order is slots → queue everywhere.
fn push_to_peer(mut job: Job, from: usize, pshared: &PoolShared) -> std::result::Result<(), Job> {
    let slots = lock_clean(&pshared.slots);
    let mut order: Vec<usize> = (0..slots.len()).collect();
    order.sort_by_key(|&i| {
        (
            slots[i].shared.health() != ReplicaHealth::Healthy,
            i == from,
            slots[i].queue.len() + slots[i].shared.active.load(Ordering::SeqCst),
        )
    });
    let prio = job.req.priority;
    for &i in &order {
        match slots[i].queue.try_push(job, prio) {
            Ok(()) => return Ok(()),
            Err(e) => job = e.into_inner(),
        }
    }
    Err(job)
}

/// Mark a redirect on a sampled trace: an instant `redirect` span, plus
/// (for jobs whose queue span was already closed) a reopened `queue`
/// span covering the time back in a peer's queue. One submission still
/// commits exactly one trace — redirects extend it, never fork it.
fn mark_redirect(trace: &mut Option<Box<ReqTrace>>, reopen_queue: bool) {
    if let Some(t) = trace.as_mut() {
        let now = t.now_ns();
        t.record("redirect", TRACK_REQUEST, now, now);
        if reopen_queue {
            t.begin("queue");
        }
    }
}

/// Bytes of `est` not covered by the shared-prefix charge.
fn unique_of(est: usize, charge: &Option<PrefixCharge>) -> usize {
    est.saturating_sub(charge.as_ref().map(|c| c.bytes).unwrap_or(0))
}

/// Hang collected engine segments (upload/dispatch/download/combine,
/// prefix lookups) under span `parent`, each on its own track.
fn record_segs(t: &mut ReqTrace, parent: usize, segs: &[Seg]) {
    for sg in segs {
        let i = t.record_under(parent, sg.name, sg.track(), sg.start_ns, sg.end_ns);
        if let Some(sh) = sg.shard {
            t.attr_u64_on(i, "shard", sh as u64);
        }
        if sg.overlap {
            // Marks work that ran concurrently with an in-flight
            // dispatch (the pipelined engine's hidden uploads).
            t.attr_u64_on(i, "overlap", 1);
        }
    }
}

/// Trace stats from a finished generation's result.
fn stats_of(res: &GenerateResult) -> TraceStats {
    TraceStats {
        tokens: res.tokens.len() as u64,
        flops_total: res.flops.total,
        relative_flops: res.relative_flops,
        prefix_hit: res.prefix_hit,
    }
}

/// Commit a job's trace (if sampled) for a request that never reached
/// the step scheduler. Runs *before* the terminal event is sent, so the
/// HTTP layer can fetch the trace as soon as the stream ends.
fn commit_job_trace(
    tracer: &TraceRecorder,
    replica_id: usize,
    job: &mut Job,
    outcome: Outcome,
) {
    if let Some(t) = job.trace.take() {
        tracer.commit(t, replica_id, outcome, TraceStats::default());
    }
}

/// Account a job that never entered the step scheduler (canceled,
/// expired, oversize, or failed at begin). The caller has already
/// counted it in `rshared.active`.
fn settle_job(
    job: &Job,
    kind: Terminal,
    msg: &str,
    rshared: &ReplicaShared,
    pshared: &PoolShared,
    m: &ReplicaMetrics,
) {
    settle_terminal(kind, msg, &job.events, rshared, pshared, m, true);
    close_stream(&job.events, job.req.profile.as_deref(), job.enqueued, false, pshared, &m.registry);
    lock_clean(&pshared.cancels).remove(&job.id);
}

/// Close out the pool-wide stream accounting for a terminated request.
/// No-op for buffered sinks. Must run exactly once per streaming
/// request, on whichever terminal path retires it.
fn close_stream(
    sink: &EventSink,
    profile: Option<&str>,
    enqueued: Instant,
    was_parked: bool,
    pshared: &PoolShared,
    metrics: &Registry,
) {
    if !sink.is_stream() {
        return;
    }
    if was_parked {
        pshared.streams_parked.fetch_sub(1, Ordering::Relaxed);
    }
    pshared.streams_active.fetch_sub(1, Ordering::Relaxed);
    pshared.streams_completed.fetch_add(1, Ordering::Relaxed);
    let secs = enqueued.elapsed().as_secs_f64();
    metrics.histogram("fastav_stream_duration_seconds").observe(secs);
    if let Some(p) = profile {
        metrics
            .histogram(&labeled("fastav_stream_duration_seconds", "profile", p))
            .observe(secs);
    }
}

fn settle_terminal(
    kind: Terminal,
    msg: &str,
    events: &EventSink,
    rshared: &ReplicaShared,
    pshared: &PoolShared,
    m: &ReplicaMetrics,
    decrement_active: bool,
) {
    match kind {
        Terminal::Canceled => {
            m.canceled_c.inc();
            pshared.canceled.fetch_add(1, Ordering::SeqCst);
        }
        Terminal::Expired => {
            m.expired_c.inc();
            pshared.expired.fetch_add(1, Ordering::SeqCst);
        }
        Terminal::Failed => {
            m.failed_c.inc();
            pshared.failed.fetch_add(1, Ordering::SeqCst);
        }
    }
    // The receiver may be gone (client disconnect) — terminal
    // accounting must not depend on anyone listening.
    events.send_error(msg.to_string());
    if decrement_active {
        rshared.active.fetch_sub(1, Ordering::SeqCst);
    }
}
