//! One replica: an OS thread that owns one engine and interleaves many
//! in-flight generations over it.
//!
//! PJRT handles are not `Send`, so the engine is constructed *on* this
//! thread and never leaves it; the replica is therefore the sharding
//! unit of the pool. Inside the thread, scheduling is iteration-level:
//! the loop alternates between admitting queued jobs (under the
//! [`Admission`] KV-byte budget) and advancing exactly one generation
//! by one quantum, as chosen by the [`StepScheduler`]. Cancellation and
//! deadlines are checked at every admission and before every quantum,
//! so a canceled long generation stops within one step.

use std::sync::atomic::Ordering;
use std::sync::mpsc::Sender;
use std::sync::Arc;
use std::time::Instant;

use anyhow::Result;

use crate::coordinator::{Event, GenRequest, SchedulerQueue};
use crate::kvcache::PrefixCache;
use crate::metrics::{labeled, occupancy_bucket, Registry, OCCUPANCY_BUCKETS};
use crate::model::{GenerateResult, Generation, ModelEngine, RequestInput, StepEvent};
use crate::trace::{
    collect_segs, Outcome, ReqTrace, Seg, TraceRecorder, TraceStats, TRACK_REQUEST,
};

use super::admission::{Admission, Admit, PrefixCharge};
use super::step_scheduler::StepScheduler;
use super::{PoolConfig, PoolShared, ReplicaShared, Terminal};

/// The engine surface a replica drives. [`ModelEngine`] is the real
/// implementation; tests swap in a mock so the pool's scheduling and
/// conservation properties run without AOT artifacts.
pub trait ReplicaEngine {
    type Gen;

    /// Start a generation (embed + fused front + global pruning — or a
    /// mid-sequence resume from the shared prefix cache on a hit).
    fn begin(&mut self, req: &GenRequest) -> Result<Self::Gen>;

    /// Advance one quantum (one prefill layer or one decode step).
    fn step(&mut self, gen: &mut Self::Gen) -> Result<StepEvent>;

    /// Whether `gen` is decode-ready (prefill complete, not done) — the
    /// eligibility test for fused decode batching. The default `false`
    /// keeps engines without a batched kernel on the single-step path.
    fn is_decoding(&self, _gen: &Self::Gen) -> bool {
        false
    }

    /// Largest number of decode-ready generations [`Self::step_batch`]
    /// can advance in one fused dispatch (1 = no batching).
    fn max_decode_batch(&self) -> usize {
        1
    }

    /// Advance several decode-ready generations one token each in a
    /// single fused dispatch, returning one event per generation in
    /// order. Default: sequential single steps.
    fn step_batch(&mut self, gens: &mut [&mut Self::Gen]) -> Result<Vec<StepEvent>> {
        let mut out = Vec::with_capacity(gens.len());
        for g in gens.iter_mut() {
            out.push(self.step(g)?);
        }
        Ok(out)
    }

    /// Whether the generation has emitted its final token.
    fn is_done(&self, gen: &Self::Gen) -> bool;

    /// Consume the generation into its result (partial on abort).
    fn finish(&mut self, gen: Self::Gen) -> GenerateResult;

    /// Current KV bytes pinned by this generation.
    fn kv_bytes(&self, gen: &Self::Gen) -> usize;

    /// Conservative pre-admission KV-byte estimate for a request.
    fn estimate_bytes(&self, req: &GenRequest) -> usize;

    /// Hook: the pool hands every engine the process-wide prefix cache
    /// at startup. Engines that can reuse AV prefixes store it; the
    /// default ignores it.
    fn attach_prefix_cache(&mut self, _cache: Arc<PrefixCache>, _replica: usize) {}

    /// The shareable (already-resident) portion of `estimate_bytes`, as
    /// a refcounted charge so admission counts shared prefix blocks once
    /// across concurrent borrowers. `None` = everything is unique.
    fn prefix_probe(&self, _req: &GenRequest) -> Option<PrefixCharge> {
        None
    }

    /// Whether `gen` resumed from a cached AV prefix (observability
    /// only: names the trace's startup span `prefix_resume` vs `begin`).
    fn prefix_hit(&self, _gen: &Self::Gen) -> bool {
        false
    }
}

impl ReplicaEngine for ModelEngine {
    type Gen = Generation;

    fn begin(&mut self, req: &GenRequest) -> Result<Generation> {
        let input = RequestInput {
            prompt: &req.prompt,
            segments: &req.segments,
            frame_of: &req.frame_of,
        };
        // Per-request plan resolution: the spec that traveled with the
        // request becomes this generation's engine plan here, at the
        // engine boundary — there is no engine-global plan.
        self.begin_generation(&input, &req.options())
    }

    fn step(&mut self, gen: &mut Generation) -> Result<StepEvent> {
        self.step_generation(gen)
    }

    fn is_decoding(&self, gen: &Generation) -> bool {
        gen.is_decoding()
    }

    fn max_decode_batch(&self) -> usize {
        ModelEngine::max_decode_batch(self)
    }

    fn step_batch(&mut self, gens: &mut [&mut Generation]) -> Result<Vec<StepEvent>> {
        self.step_decode_batch(gens)
    }

    fn is_done(&self, gen: &Generation) -> bool {
        gen.is_done()
    }

    fn finish(&mut self, gen: Generation) -> GenerateResult {
        self.finish_generation(gen)
    }

    fn kv_bytes(&self, gen: &Generation) -> usize {
        gen.kv_bytes()
    }

    fn estimate_bytes(&self, req: &GenRequest) -> usize {
        // Admission charges the spec's *effective keep budget*: for a
        // query-independent global stage the post-prune live set is
        // computable host-side, so an aggressive profile reserves far
        // fewer KV bytes than a quality one on the same pool.
        self.estimate_kv_bytes_planned(
            req.spec.plan(),
            &req.segments,
            &req.frame_of,
            req.max_gen,
        )
    }

    fn attach_prefix_cache(&mut self, cache: Arc<PrefixCache>, _replica: usize) {
        self.set_prefix_cache(cache);
    }

    fn prefix_probe(&self, req: &GenRequest) -> Option<PrefixCharge> {
        self.prefix_shared_estimate(&req.prompt, &req.segments, &req.frame_of, req.spec.plan())
            .map(|(key, bytes)| PrefixCharge { key, bytes })
    }

    fn prefix_hit(&self, gen: &Generation) -> bool {
        gen.prefix_hit()
    }
}

/// A queued request (pool-internal).
pub(crate) struct Job {
    pub id: u64,
    pub req: GenRequest,
    pub enqueued: Instant,
    pub deadline: Option<Instant>,
    pub cancel: Arc<std::sync::atomic::AtomicBool>,
    pub events: Sender<Event>,
    /// Sampled lifecycle trace (None on the untraced path — which is
    /// every request when `--trace-sample 0`).
    pub trace: Option<Box<ReqTrace>>,
}

/// One admitted, in-flight generation.
struct Active<G> {
    id: u64,
    gen: G,
    cancel: Arc<std::sync::atomic::AtomicBool>,
    deadline: Option<Instant>,
    events: Sender<Event>,
    /// Submission time — end-to-end `fastav_generate_seconds` and TTFT
    /// measure from here (SLO semantics: queue time counts).
    enqueued: Instant,
    /// Unique (non-shared) bytes reserved with the admission controller.
    est_bytes: usize,
    /// Shared-prefix charge reserved alongside (refcounted; see
    /// [`Admission::release_prefixed`]).
    prefix_charge: Option<PrefixCharge>,
    /// Decode-batch compatibility class of the request's pruning spec
    /// ([`crate::policy::PruningSpec::decode_class`]); fused quanta only
    /// mix entries of one class.
    spec_class: u64,
    /// Policy profile label for the per-profile latency histogram.
    profile: Option<String>,
    /// Whether the first token was already streamed (TTFT fires once).
    got_first_token: bool,
    trace: Option<Box<ReqTrace>>,
}

/// Pre-resolved metric handles for one replica thread.
struct ReplicaMetrics {
    active_g: Arc<crate::metrics::Gauge>,
    kv_g: Arc<crate::metrics::Gauge>,
    sps_g: Arc<crate::metrics::Gauge>,
    steps_c: Arc<crate::metrics::Counter>,
    queue_hist: Arc<crate::metrics::Histogram>,
    gen_hist: Arc<crate::metrics::Histogram>,
    ttft_hist: Arc<crate::metrics::Histogram>,
    prefill_hist: Arc<crate::metrics::Histogram>,
    tok_hist: Arc<crate::metrics::Histogram>,
    completed_c: Arc<crate::metrics::Counter>,
    failed_c: Arc<crate::metrics::Counter>,
    canceled_c: Arc<crate::metrics::Counter>,
    expired_c: Arc<crate::metrics::Counter>,
    tokens_c: Arc<crate::metrics::Counter>,
    prefix_tokens_c: Arc<crate::metrics::Counter>,
    kv_peak: Arc<crate::metrics::Gauge>,
    /// Decode-batch occupancy distribution, one counter per
    /// [`OCCUPANCY_BUCKETS`] size class (histogram-style gauges).
    occ: Vec<Arc<crate::metrics::Counter>>,
    batched_steps_c: Arc<crate::metrics::Counter>,
    batched_tokens_c: Arc<crate::metrics::Counter>,
}

impl ReplicaMetrics {
    fn new(metrics: &Registry, replica: usize) -> ReplicaMetrics {
        let l = replica.to_string();
        ReplicaMetrics {
            active_g: metrics.gauge(&labeled("fastav_replica_active_requests", "replica", &l)),
            kv_g: metrics.gauge(&labeled("fastav_replica_kv_bytes", "replica", &l)),
            sps_g: metrics.gauge(&labeled("fastav_replica_steps_per_second", "replica", &l)),
            steps_c: metrics.counter(&labeled("fastav_replica_steps_total", "replica", &l)),
            queue_hist: metrics.histogram("fastav_queue_seconds"),
            gen_hist: metrics.histogram("fastav_generate_seconds"),
            ttft_hist: metrics.histogram("fastav_ttft_seconds"),
            prefill_hist: metrics.histogram("fastav_prefill_seconds"),
            tok_hist: metrics.histogram("fastav_decode_token_seconds"),
            completed_c: metrics.counter("fastav_requests_completed_total"),
            failed_c: metrics.counter("fastav_requests_failed_total"),
            canceled_c: metrics.counter("fastav_requests_canceled_total"),
            expired_c: metrics.counter("fastav_requests_expired_total"),
            tokens_c: metrics.counter("fastav_tokens_generated_total"),
            prefix_tokens_c: metrics.counter("fastav_prefix_tokens_reused_total"),
            kv_peak: metrics.gauge("fastav_kv_peak_bytes"),
            occ: OCCUPANCY_BUCKETS
                .iter()
                .map(|sz| metrics.counter(&labeled("fastav_decode_batch_occupancy", "size", sz)))
                .collect(),
            batched_steps_c: metrics.counter("fastav_decode_batched_steps_total"),
            batched_tokens_c: metrics.counter("fastav_decode_batched_tokens_total"),
        }
    }
}

/// The replica thread body: admit → step → account, until the queue is
/// closed and drained and no generation is in flight.
#[allow(clippy::too_many_arguments)]
pub(crate) fn replica_loop<E: ReplicaEngine>(
    replica_id: usize,
    mut engine: E,
    cfg: &PoolConfig,
    queue: &SchedulerQueue<Job>,
    rshared: &ReplicaShared,
    pshared: &PoolShared,
    metrics: &Registry,
    prefix: Option<Arc<PrefixCache>>,
    tracer: &Arc<TraceRecorder>,
) {
    let m = ReplicaMetrics::new(metrics, replica_id);
    if let Some(c) = prefix.clone() {
        engine.attach_prefix_cache(c, replica_id);
    }
    // A replica is a device group: admission charges KV bytes against
    // the group's pooled capacity (per-device budget × tp_degree).
    let mut admission = Admission::new(cfg.group_kv_budget_bytes(), cfg.max_inflight);
    let mut sched = StepScheduler::new();
    let mut active: Vec<Active<E::Gen>> = Vec::new();
    let mut parked: Option<Job> = None;
    let mut rate_steps = 0u64;
    let mut rate_t0 = Instant::now();

    'outer: loop {
        // ---- Admission: pull queued jobs into the step scheduler. ----
        while admission.has_slot() {
            // A parked (budget-deferred) job is already counted as
            // in-flight; fresh pops are counted on arrival.
            let mut counted = false;
            let job = if let Some(j) = parked.take() {
                counted = true;
                Some(j)
            } else if active.is_empty() {
                match queue.pop_blocking() {
                    Some(j) => Some(j),
                    None => break 'outer, // closed + drained, nothing running
                }
            } else {
                queue.try_pop_fair()
            };
            let Some(mut job) = job else { break };
            if !counted {
                rshared.active.fetch_add(1, Ordering::SeqCst);
            }
            // A freshly popped trace has its `queue` span open — close
            // it now. On a parked retry the stack is already back at
            // the root and `end()` is a no-op.
            if let Some(t) = job.trace.as_mut() {
                t.end();
            }
            if job.cancel.load(Ordering::SeqCst) {
                commit_job_trace(tracer, replica_id, &mut job, Outcome::Canceled);
                settle_job(&job, Terminal::Canceled, "canceled before start", rshared, pshared, &m);
                continue;
            }
            if job.deadline.is_some_and(|d| Instant::now() >= d) {
                commit_job_trace(tracer, replica_id, &mut job, Outcome::Expired);
                settle_job(&job, Terminal::Expired, "deadline exceeded in queue", rshared, pshared, &m);
                continue;
            }
            if let Some(t) = job.trace.as_mut() {
                t.begin("admit");
            }
            let est = engine.estimate_bytes(&job.req);
            // Split the estimate: bytes the request will borrow from a
            // resident prefix entry are charged once across borrowers.
            let probe_t0 = job.trace.as_ref().map(|t| t.now_ns());
            let charge = engine.prefix_probe(&job.req);
            if let Some(t) = job.trace.as_mut() {
                let now = t.now_ns();
                let s = t.record("prefix_probe", TRACK_REQUEST, probe_t0.unwrap_or(now), now);
                if let Some(c) = &charge {
                    t.attr_u64_on(s, "shared_bytes", c.bytes as u64);
                }
            }
            let verdict = admission.check_prefixed(unique_of(est, &charge), charge);
            if let Some(t) = job.trace.as_mut() {
                t.attr_str("outcome", verdict.name());
                t.end();
            }
            match verdict {
                Admit::Granted => {}
                Admit::Defer => {
                    // Re-examined once a running generation releases
                    // budget; stays counted as in-flight meanwhile.
                    parked = Some(job);
                    break;
                }
                Admit::Oversize => {
                    commit_job_trace(tracer, replica_id, &mut job, Outcome::Failed);
                    settle_job(
                        &job,
                        Terminal::Failed,
                        &format!(
                            "request needs ~{} KV bytes, over the replica budget {}",
                            est,
                            admission.budget_bytes()
                        ),
                        rshared,
                        pshared,
                        &m,
                    );
                    continue;
                }
            }
            let unique = unique_of(est, &charge);
            m.queue_hist.observe(job.enqueued.elapsed().as_secs_f64());
            let spec_class = job.req.spec.decode_class();
            // `begin` is one quantum-sized unit of engine work (embed +
            // fused front + global prune, or a prefix-cache resume);
            // traced requests time it and collect the engine's internal
            // segments (prefix lookups, mesh upload/dispatch/download).
            let begin_t0 = job.trace.as_ref().map(|t| t.now_ns());
            let (begun, begin_segs) = if job.trace.is_some() {
                collect_segs(tracer.clock(), || engine.begin(&job.req))
            } else {
                (engine.begin(&job.req), Vec::new())
            };
            match begun {
                Ok(gen) => {
                    if let Some(t) = job.trace.as_mut() {
                        let name =
                            if engine.prefix_hit(&gen) { "prefix_resume" } else { "begin" };
                        let now = t.now_ns();
                        let s = t.record(name, TRACK_REQUEST, begin_t0.unwrap_or(now), now);
                        t.attr_u64_on(s, "prompt_tokens", job.req.prompt.len() as u64);
                        record_segs(t, s, &begin_segs);
                    }
                    sched.admit_with_affinity(
                        job.id,
                        job.req.priority,
                        job.deadline,
                        charge.map(|c| c.key),
                    );
                    active.push(Active {
                        id: job.id,
                        gen,
                        cancel: job.cancel,
                        deadline: job.deadline,
                        events: job.events,
                        enqueued: job.enqueued,
                        est_bytes: unique,
                        prefix_charge: charge,
                        spec_class,
                        profile: job.req.profile.clone(),
                        got_first_token: false,
                        trace: job.trace.take(),
                    });
                }
                Err(e) => {
                    if let Some(t) = job.trace.as_mut() {
                        let now = t.now_ns();
                        t.record("begin", TRACK_REQUEST, begin_t0.unwrap_or(now), now);
                    }
                    admission.release_prefixed(unique, charge);
                    commit_job_trace(tracer, replica_id, &mut job, Outcome::Failed);
                    settle_job(&job, Terminal::Failed, &format!("{:#}", e), rshared, pshared, &m);
                }
            }
        }
        m.active_g.set(active.len() as u64);
        if active.is_empty() {
            continue; // back to the blocking pop (or retry the parked job)
        }

        // ---- Cancellation/deadline sweep over every in-flight entry
        // (a batched quantum advances many at once, so all must be
        // checked, not just one pick). ----
        let now = Instant::now();
        let mut i = 0;
        while i < active.len() {
            let kind = if active[i].cancel.load(Ordering::SeqCst) {
                Some((Terminal::Canceled, "canceled"))
            } else if active[i].deadline.is_some_and(|d| now >= d) {
                Some((Terminal::Expired, "deadline exceeded"))
            } else {
                None
            };
            match kind {
                Some((kind, msg)) => {
                    retire_at(&mut engine, &mut active, &mut sched, i, kind, msg,
                              &mut admission, rshared, pshared, &m, tracer, replica_id);
                }
                None => i += 1,
            }
        }
        m.active_g.set(active.len() as u64);
        if active.is_empty() {
            continue;
        }

        // ---- One scheduling quantum: a chunked-prefill step for one
        // entry, or one fused decode batch over the decode-ready set
        // (quantum model: prefill = 1 chunk, decode = 1 batch). ----
        let max_b = match cfg.max_decode_batch {
            0 => engine.max_decode_batch(),
            n => n.min(engine.max_decode_batch()),
        };
        let ready: Vec<bool> = active.iter().map(|a| engine.is_decoding(&a.gen)).collect();
        let classes: Vec<u64> = active.iter().map(|a| a.spec_class).collect();
        let picked = sched.pick_batch_classed(max_b, &ready, &classes);
        if picked.is_empty() {
            continue;
        }
        let decode_quantum = ready[picked[0]];

        // Traced participants share one quantum timing: measure the
        // dispatch once on the recorder clock, collect the engine's
        // internal segments, and record a closed span into each traced
        // trace below. Untraced quanta skip all of it.
        let any_traced = picked.iter().any(|&i| active[i].trace.is_some());
        let q_t0 = if any_traced { Some(tracer.clock().now_ns()) } else { None };
        let (stepped, q_segs) = if any_traced {
            collect_segs(tracer.clock(), || step_picked(&mut engine, &mut active, &picked))
        } else {
            (step_picked(&mut engine, &mut active, &picked), Vec::new())
        };
        let q_t1 = q_t0.map(|_| tracer.clock().now_ns());

        match stepped {
            Ok(events) => {
                debug_assert_eq!(events.len(), picked.len());
                if decode_quantum {
                    let b = picked.len();
                    m.occ[occupancy_bucket(b)].inc();
                    rshared.batch_quanta.fetch_add(1, Ordering::Relaxed);
                    rshared.batch_tokens.fetch_add(b as u64, Ordering::Relaxed);
                    if b >= 2 {
                        m.batched_steps_c.inc();
                        m.batched_tokens_c.add(b as u64);
                    }
                }
                let mut finished: Vec<usize> = Vec::new();
                for (&idx, ev) in picked.iter().zip(&events) {
                    let entry = &mut active[idx];
                    if let (Some(t0), Some(t1)) = (q_t0, q_t1) {
                        if let Some(t) = entry.trace.as_mut() {
                            let s = if decode_quantum {
                                let s = t.record("decode_quantum", TRACK_REQUEST, t0, t1);
                                t.attr_u64_on(s, "batch", picked.len() as u64);
                                t.attr_u64_on(s, "class", entry.spec_class);
                                s
                            } else {
                                let s = t.record("prefill_chunk", TRACK_REQUEST, t0, t1);
                                if let StepEvent::Prefilled { layer } = ev {
                                    t.attr_u64_on(s, "layer", *layer as u64);
                                }
                                s
                            };
                            t.attr_u64_on(s, "seq", sched.quantum_seq());
                            record_segs(t, s, &q_segs);
                        }
                    }
                    match ev {
                        StepEvent::Token(t) => {
                            let _ = entry.events.send(Event::Token(*t));
                            if !entry.got_first_token {
                                entry.got_first_token = true;
                                m.ttft_hist.observe(entry.enqueued.elapsed().as_secs_f64());
                                if let Some(tr) = entry.trace.as_mut() {
                                    tr.mark_first_token();
                                }
                            }
                            m.steps_c.inc();
                            rshared.steps_total.fetch_add(1, Ordering::Relaxed);
                            rate_steps += 1;
                            if engine.is_done(&entry.gen) {
                                finished.push(idx);
                            }
                        }
                        StepEvent::Prefilled { .. } => {
                            m.steps_c.inc();
                            rshared.steps_total.fetch_add(1, Ordering::Relaxed);
                            rate_steps += 1;
                        }
                        StepEvent::Done => finished.push(idx),
                    }
                }
                // Retire completed generations back-to-front so the
                // remaining indices stay valid.
                for &idx in finished.iter().rev() {
                    let mut a = active.remove(idx);
                    sched.remove(idx);
                    let res = engine.finish(a.gen);
                    // End-to-end latency (submit → finish). For traced
                    // requests the histogram observes *exactly* the
                    // trace root's duration, so `/v1/trace/{id}` and
                    // `fastav_generate_seconds` can never disagree.
                    let gen_secs = match a.trace.take() {
                        Some(t) => tracer.commit(
                            t,
                            replica_id,
                            Outcome::Completed,
                            stats_of(&res),
                        ),
                        None => a.enqueued.elapsed().as_secs_f64(),
                    };
                    m.gen_hist.observe(gen_secs);
                    if let Some(p) = &a.profile {
                        metrics
                            .histogram(&labeled("fastav_generate_seconds", "profile", p))
                            .observe(gen_secs);
                    }
                    m.prefill_hist.observe(res.prefill_seconds);
                    if res.decode_steps > 0 {
                        m.tok_hist.observe(res.decode_seconds / res.decode_steps as f64);
                    }
                    m.kv_peak.max(res.peak_kv_bytes as u64);
                    m.tokens_c.add(res.tokens.len() as u64);
                    m.prefix_tokens_c.add(res.prefix_tokens_reused as u64);
                    m.completed_c.inc();
                    pshared.completed.fetch_add(1, Ordering::SeqCst);
                    rshared.completed.fetch_add(1, Ordering::SeqCst);
                    let _ = a.events.send(Event::Done(Box::new(res)));
                    admission.release_prefixed(a.est_bytes, a.prefix_charge);
                    pshared.cancels.lock().unwrap().remove(&a.id);
                    rshared.active.fetch_sub(1, Ordering::SeqCst);
                }
            }
            Err(e) => {
                // The fused dispatch is all-or-nothing: every generation
                // in it fails with the same engine error.
                let msg = format!("{:#}", e);
                for &idx in picked.iter().rev() {
                    retire_at(&mut engine, &mut active, &mut sched, idx,
                              Terminal::Failed, &msg, &mut admission, rshared, pshared, &m,
                              tracer, replica_id);
                }
            }
        }
        m.active_g.set(active.len() as u64);

        // ---- Gauges: KV footprint + steps/s. ----
        let kv_now: usize = active.iter().map(|a| engine.kv_bytes(&a.gen)).sum();
        rshared.kv_bytes.store(kv_now as u64, Ordering::Relaxed);
        m.kv_g.set(kv_now as u64);
        let dt = rate_t0.elapsed().as_secs_f64();
        if dt >= 0.5 {
            let sps = (rate_steps as f64 / dt).round() as u64;
            rshared.steps_per_sec.store(sps, Ordering::Relaxed);
            m.sps_g.set(sps);
            // Block-pool gauges drift with every append/compact, not only
            // with cache operations — refresh them on the rate tick.
            if let Some(c) = &prefix {
                c.refresh_gauges();
            }
            rate_steps = 0;
            rate_t0 = Instant::now();
        }
    }
}

/// Advance the picked set by one quantum: a single step when the pick
/// is one generation, one fused decode dispatch otherwise.
fn step_picked<E: ReplicaEngine>(
    engine: &mut E,
    active: &mut [Active<E::Gen>],
    picked: &[usize],
) -> Result<Vec<StepEvent>> {
    if picked.len() == 1 {
        return engine.step(&mut active[picked[0]].gen).map(|ev| vec![ev]);
    }
    // Disjoint &mut borrows of the picked generations (ascending
    // indices) for one fused dispatch.
    let mut gens: Vec<&mut E::Gen> = Vec::with_capacity(picked.len());
    let mut want = picked.iter().copied().peekable();
    for (i, a) in active.iter_mut().enumerate() {
        if want.peek() == Some(&i) {
            want.next();
            gens.push(&mut a.gen);
        }
    }
    engine.step_batch(&mut gens)
}

/// Bytes of `est` not covered by the shared-prefix charge.
fn unique_of(est: usize, charge: &Option<PrefixCharge>) -> usize {
    est.saturating_sub(charge.as_ref().map(|c| c.bytes).unwrap_or(0))
}

/// Hang collected engine segments (upload/dispatch/download/combine,
/// prefix lookups) under span `parent`, each on its own track.
fn record_segs(t: &mut ReqTrace, parent: usize, segs: &[Seg]) {
    for sg in segs {
        let i = t.record_under(parent, sg.name, sg.track(), sg.start_ns, sg.end_ns);
        if let Some(sh) = sg.shard {
            t.attr_u64_on(i, "shard", sh as u64);
        }
    }
}

/// Trace stats from a finished generation's result.
fn stats_of(res: &GenerateResult) -> TraceStats {
    TraceStats {
        tokens: res.tokens.len() as u64,
        flops_total: res.flops.total,
        relative_flops: res.relative_flops,
        prefix_hit: res.prefix_hit,
    }
}

/// Commit a job's trace (if sampled) for a request that never reached
/// the step scheduler. Runs *before* the terminal event is sent, so the
/// HTTP layer can fetch the trace as soon as the stream ends.
fn commit_job_trace(
    tracer: &TraceRecorder,
    replica_id: usize,
    job: &mut Job,
    outcome: Outcome,
) {
    if let Some(t) = job.trace.take() {
        tracer.commit(t, replica_id, outcome, TraceStats::default());
    }
}

/// Retire in-flight entry `idx` into a terminal state: drop its partial
/// generation, settle counters/events, and release its admission charge.
#[allow(clippy::too_many_arguments)]
fn retire_at<E: ReplicaEngine>(
    engine: &mut E,
    active: &mut Vec<Active<E::Gen>>,
    sched: &mut StepScheduler,
    idx: usize,
    kind: Terminal,
    msg: &str,
    admission: &mut Admission,
    rshared: &ReplicaShared,
    pshared: &PoolShared,
    m: &ReplicaMetrics,
    tracer: &TraceRecorder,
    replica_id: usize,
) {
    let mut a = active.remove(idx);
    sched.remove(idx);
    let res = engine.finish(a.gen);
    if let Some(t) = a.trace.take() {
        let outcome = match kind {
            Terminal::Canceled => Outcome::Canceled,
            Terminal::Expired => Outcome::Expired,
            Terminal::Failed => Outcome::Failed,
        };
        tracer.commit(t, replica_id, outcome, stats_of(&res));
    }
    drop(res);
    settle_terminal(kind, msg, &a.events, rshared, pshared, m, false);
    admission.release_prefixed(a.est_bytes, a.prefix_charge);
    pshared.cancels.lock().unwrap().remove(&a.id);
    rshared.active.fetch_sub(1, Ordering::SeqCst);
}

/// Account a job that never entered the step scheduler (canceled,
/// expired, oversize, or failed at begin). The caller has already
/// counted it in `rshared.active`.
fn settle_job(
    job: &Job,
    kind: Terminal,
    msg: &str,
    rshared: &ReplicaShared,
    pshared: &PoolShared,
    m: &ReplicaMetrics,
) {
    settle_terminal(kind, msg, &job.events, rshared, pshared, m, true);
    pshared.cancels.lock().unwrap().remove(&job.id);
}

fn settle_terminal(
    kind: Terminal,
    msg: &str,
    events: &Sender<Event>,
    rshared: &ReplicaShared,
    pshared: &PoolShared,
    m: &ReplicaMetrics,
    decrement_active: bool,
) {
    match kind {
        Terminal::Canceled => {
            m.canceled_c.inc();
            pshared.canceled.fetch_add(1, Ordering::SeqCst);
        }
        Terminal::Expired => {
            m.expired_c.inc();
            pshared.expired.fetch_add(1, Ordering::SeqCst);
        }
        Terminal::Failed => {
            m.failed_c.inc();
            pshared.failed.fetch_add(1, Ordering::SeqCst);
        }
    }
    let _ = events.send(Event::Error(msg.to_string()));
    if decrement_active {
        rshared.active.fetch_sub(1, Ordering::SeqCst);
    }
}
