//! Iteration-level (continuous-batching-style) step scheduling inside
//! one replica.
//!
//! The replica keeps a small set of in-flight generations and asks the
//! scheduler which one to advance by **one quantum** — one back layer
//! of chunked prefill, or one decode step. Policy: weighted round-robin
//! (High gets [`HIGH_WEIGHT`] consecutive quanta, Normal one), which
//! yields a hard no-starvation bound — every in-flight generation
//! advances at least once per `HIGH_WEIGHT × n` quanta — so short
//! answers are never head-of-line blocked behind a long generation.
//!
//! The scheduler mirrors the replica's `active` vector index-for-index;
//! `admit`/`remove` keep the two in lockstep. It is engine-agnostic and
//! single-threaded, which is what makes the fairness properties
//! testable without artifacts (see `rust/tests/test_scheduling.rs`).
//!
//! Prefix affinity: entries carry the prefix-cache entry key they were
//! admitted under ([`EntryMeta::affinity`]). Affinity-aware *dispatch*
//! happens one level up (`ReplicaPool::submit` routes same-prefix
//! requests to the owning replica); within a replica every in-flight
//! generation already shares the same process-wide prefix cache and
//! engine, so reordering quanta by affinity would buy nothing and cost
//! the weighted-round-robin no-starvation bound. The key is recorded so
//! operators can see co-located prefix groups per replica.

use std::time::Instant;

use crate::coordinator::Priority;

/// Consecutive quanta a High-priority generation receives per turn.
pub const HIGH_WEIGHT: u32 = 2;

/// Scheduler-side bookkeeping for one in-flight generation.
#[derive(Debug, Clone)]
pub struct EntryMeta {
    pub id: u64,
    pub priority: Priority,
    pub deadline: Option<Instant>,
    /// Prefix-cache entry key this generation shares, if any.
    pub affinity: Option<u64>,
    /// Quanta this generation has received.
    pub steps: u64,
}

/// Weighted round-robin step scheduler for one replica.
#[derive(Debug, Default)]
pub struct StepScheduler {
    entries: Vec<EntryMeta>,
    cursor: usize,
    /// Quanta already granted to the entry under the cursor this turn.
    credits: u32,
    /// Total quanta granted over the scheduler's lifetime.
    total_steps: u64,
}

impl StepScheduler {
    pub fn new() -> StepScheduler {
        StepScheduler::default()
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    pub fn total_steps(&self) -> u64 {
        self.total_steps
    }

    /// Sequence number of the most recent quantum (1-based; 0 before
    /// any pick). Quantum spans carry it as their `seq` attribute so a
    /// trace can be lined up against the replica's scheduling order.
    pub fn quantum_seq(&self) -> u64 {
        self.total_steps
    }

    pub fn entry(&self, idx: usize) -> &EntryMeta {
        &self.entries[idx]
    }

    /// Register a newly admitted generation (appends — the replica's
    /// `active` vector must push in the same order).
    pub fn admit(&mut self, id: u64, priority: Priority, deadline: Option<Instant>) {
        self.admit_with_affinity(id, priority, deadline, None);
    }

    /// [`Self::admit`] recording the prefix-cache entry key the
    /// generation was admitted under (observability; see module docs).
    pub fn admit_with_affinity(
        &mut self,
        id: u64,
        priority: Priority,
        deadline: Option<Instant>,
        affinity: Option<u64>,
    ) {
        self.entries.push(EntryMeta { id, priority, deadline, affinity, steps: 0 });
    }

    /// In-flight generations sharing `affinity` (co-located prefix group).
    pub fn affinity_count(&self, affinity: u64) -> usize {
        self.entries
            .iter()
            .filter(|e| e.affinity == Some(affinity))
            .count()
    }

    /// Pick the entry to advance one quantum. Weighted round-robin:
    /// stays on the current entry until its weight is spent, then moves
    /// on; wraps at the end.
    pub fn pick(&mut self) -> Option<usize> {
        if self.entries.is_empty() {
            return None;
        }
        if self.cursor >= self.entries.len() {
            self.cursor = 0;
            self.credits = 0;
        }
        let idx = self.cursor;
        let weight = match self.entries[idx].priority {
            Priority::High => HIGH_WEIGHT,
            Priority::Normal => 1,
        };
        self.entries[idx].steps += 1;
        self.total_steps += 1;
        self.credits += 1;
        if self.credits >= weight {
            self.credits = 0;
            self.cursor = (idx + 1) % self.entries.len();
        }
        Some(idx)
    }

    /// Pick the entries to advance this quantum under the batched-decode
    /// quantum model (prefill = 1 chunk, decode = 1 batch):
    ///
    /// * If the round-robin cursor lands on an entry that is **not**
    ///   decode-ready (`ready[i] == false`, i.e. still prefilling), this
    ///   degrades to [`Self::pick`] — chunked-prefill fairness and the
    ///   weighted no-starvation bound are unchanged.
    /// * If it lands on a decode-ready entry, up to `max_b` decode-ready
    ///   entries (scanning from the cursor, wrapping) are drained into
    ///   one batch; every picked entry advances this quantum, so batching
    ///   strictly dominates the weighted share each would have received.
    ///   Leftover decoders beyond `max_b` are first in line next quantum
    ///   (the cursor advances by one, and the scan starts there).
    ///
    /// `ready` must be index-aligned with the entries (the replica's
    /// `active` vector). Returns ascending indices; empty iff no entries.
    pub fn pick_batch(&mut self, max_b: usize, ready: &[bool]) -> Vec<usize> {
        self.pick_batch_classed(max_b, ready, &[])
    }

    /// [`Self::pick_batch`] with per-entry **spec-compatibility
    /// classes** (aligned like `ready`; an empty slice means one shared
    /// class). A fused batch drains only decode-ready entries whose
    /// class matches the primary's — requests under decode-time pruning
    /// policies fuse only with identical policies (their caches compact
    /// mid-quantum, so mixing policies would thrash the joint bucket
    /// pick), while everything else falls back to smaller batches or
    /// single steps. Specs without decode-time pruning all share class
    /// `0` ([`crate::policy::PruningSpec::decode_class`]), so ordinary
    /// mixed-profile traffic still fuses at full occupancy.
    pub fn pick_batch_classed(
        &mut self,
        max_b: usize,
        ready: &[bool],
        classes: &[u64],
    ) -> Vec<usize> {
        self.pick_batch_gated(max_b, ready, classes, &[])
    }

    /// [`Self::pick_batch_classed`] with a per-entry **blocked mask**
    /// (aligned like `ready`; empty = nothing blocked). A blocked entry
    /// — a streaming request parked on a slow consumer — is never
    /// granted a quantum in any form: not as the batch primary, not as
    /// a batchmate, and not via the single-step prefill fallback (which
    /// would otherwise step the cursor entry regardless of readiness).
    /// The cursor skips over blocked entries without charging them
    /// steps, so their round-robin position survives the park; when
    /// every entry is blocked there is no quantum (empty pick with a
    /// non-empty scheduler — the replica loop yields briefly instead of
    /// spinning).
    pub fn pick_batch_gated(
        &mut self,
        max_b: usize,
        ready: &[bool],
        classes: &[u64],
        blocked: &[bool],
    ) -> Vec<usize> {
        assert_eq!(ready.len(), self.entries.len(), "ready mask misaligned");
        assert!(
            classes.is_empty() || classes.len() == self.entries.len(),
            "classes misaligned"
        );
        assert!(
            blocked.is_empty() || blocked.len() == self.entries.len(),
            "blocked mask misaligned"
        );
        if self.entries.is_empty() {
            return Vec::new();
        }
        if self.cursor >= self.entries.len() {
            self.cursor = 0;
            self.credits = 0;
        }
        let is_blocked = |i: usize| !blocked.is_empty() && blocked[i];
        let n = self.entries.len();
        if is_blocked(self.cursor) {
            // Advance to the next runnable entry without granting the
            // parked ones anything; a fresh primary starts a fresh turn.
            let Some(off) = (1..n).find(|&o| !is_blocked((self.cursor + o) % n)) else {
                return Vec::new();
            };
            self.cursor = (self.cursor + off) % n;
            self.credits = 0;
        }
        let primary = self.cursor;
        if max_b < 2 || !ready[primary] {
            return self.pick().into_iter().collect();
        }
        let compatible = |i: usize| {
            classes.is_empty() || classes[i] == classes[primary]
        };
        let mut picked: Vec<usize> = Vec::new();
        for off in 0..n {
            let i = (primary + off) % n;
            if ready[i] && !is_blocked(i) && compatible(i) {
                picked.push(i);
                if picked.len() == max_b {
                    break;
                }
            }
        }
        for &i in &picked {
            self.entries[i].steps += 1;
            self.total_steps += 1;
        }
        // Rotation moves past the primary; its priority weight is moot —
        // the whole decode-ready set advanced in this quantum.
        self.credits = 0;
        self.cursor = (primary + 1) % n;
        picked.sort_unstable();
        picked
    }

    /// First entry whose deadline has passed, if any.
    pub fn first_expired(&self, now: Instant) -> Option<usize> {
        self.entries
            .iter()
            .position(|e| e.deadline.is_some_and(|d| now >= d))
    }

    /// Remove a completed/canceled entry (the replica removes the same
    /// index from its `active` vector). Preserves round-robin position.
    pub fn remove(&mut self, idx: usize) -> EntryMeta {
        let meta = self.entries.remove(idx);
        if idx < self.cursor {
            self.cursor -= 1;
        } else if idx == self.cursor {
            self.credits = 0;
        }
        if self.cursor >= self.entries.len() {
            self.cursor = 0;
        }
        meta
    }

    /// Largest step-count gap between any two in-flight entries — the
    /// observable starvation metric (bounded by `HIGH_WEIGHT` per round
    /// for entries admitted together).
    pub fn max_step_gap(&self) -> u64 {
        let min = self.entries.iter().map(|e| e.steps).min().unwrap_or(0);
        let max = self.entries.iter().map(|e| e.steps).max().unwrap_or(0);
        max - min
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn round_robin_over_normals() {
        let mut s = StepScheduler::new();
        for id in 0..3 {
            s.admit(id, Priority::Normal, None);
        }
        let picks: Vec<usize> = (0..6).map(|_| s.pick().unwrap()).collect();
        assert_eq!(picks, vec![0, 1, 2, 0, 1, 2]);
        assert_eq!(s.max_step_gap(), 0);
    }

    #[test]
    fn high_gets_weighted_share_but_normal_never_starves() {
        let mut s = StepScheduler::new();
        s.admit(1, Priority::High, None);
        s.admit(2, Priority::Normal, None);
        // One full round: High twice, Normal once.
        let picks: Vec<usize> = (0..6).map(|_| s.pick().unwrap()).collect();
        assert_eq!(picks, vec![0, 0, 1, 0, 0, 1]);
        // Normal advanced 2 of 6 quanta — bounded, not starved.
        assert_eq!(s.entry(1).steps, 2);
        assert!(s.max_step_gap() <= HIGH_WEIGHT as u64 * 2);
    }

    #[test]
    fn removal_preserves_rotation() {
        let mut s = StepScheduler::new();
        for id in 0..3 {
            s.admit(id, Priority::Normal, None);
        }
        assert_eq!(s.pick(), Some(0));
        let meta = s.remove(0); // entry 1 shifts to index 0
        assert_eq!(meta.id, 0);
        // Rotation continues from the shifted position without skipping.
        let picks: Vec<u64> = (0..4).map(|_| s.entry(s.pick().unwrap()).id).collect();
        assert_eq!(picks, vec![1, 2, 1, 2]);
    }

    #[test]
    fn pick_on_empty_is_none() {
        let mut s = StepScheduler::new();
        assert_eq!(s.pick(), None);
        s.admit(7, Priority::Normal, None);
        assert_eq!(s.pick(), Some(0));
        s.remove(0);
        assert_eq!(s.pick(), None);
    }

    #[test]
    fn affinity_recorded_per_entry() {
        let mut s = StepScheduler::new();
        s.admit_with_affinity(1, Priority::Normal, None, Some(9));
        s.admit_with_affinity(2, Priority::Normal, None, Some(9));
        s.admit(3, Priority::Normal, None);
        assert_eq!(s.affinity_count(9), 2);
        assert_eq!(s.entry(2).affinity, None);
        s.remove(0);
        assert_eq!(s.affinity_count(9), 1);
    }

    #[test]
    fn pick_batch_drains_decode_ready_set() {
        let mut s = StepScheduler::new();
        for id in 0..4 {
            s.admit(id, Priority::Normal, None);
        }
        // Entries 0, 2, 3 decoding; entry 1 still prefilling.
        let ready = vec![true, false, true, true];
        let picked = s.pick_batch(8, &ready);
        assert_eq!(picked, vec![0, 2, 3]);
        assert_eq!(s.entry(0).steps, 1);
        assert_eq!(s.entry(1).steps, 0, "prefilling entry not batched");
        assert_eq!(s.entry(2).steps, 1);
        assert_eq!(s.total_steps(), 3);
        // Cursor advanced to the prefilling entry: next quantum is its
        // chunked-prefill step, exactly as with single picks.
        assert_eq!(s.pick_batch(8, &ready), vec![1]);
        assert_eq!(s.entry(1).steps, 1);
    }

    #[test]
    fn pick_batch_respects_max_b_and_rotates_leftovers() {
        let mut s = StepScheduler::new();
        for id in 0..5 {
            s.admit(id, Priority::Normal, None);
        }
        let ready = vec![true; 5];
        let picked = s.pick_batch(4, &ready);
        assert_eq!(picked.len(), 4);
        assert_eq!(picked, vec![0, 1, 2, 3]);
        // Next quantum starts at entry 1: the leftover (4) is included.
        let picked = s.pick_batch(4, &ready);
        assert_eq!(picked, vec![1, 2, 3, 4]);
        assert_eq!(s.max_step_gap(), 1, "leftovers lag by at most one round");
    }

    #[test]
    fn pick_batch_of_one_matches_single_pick() {
        let mut s = StepScheduler::new();
        s.admit(1, Priority::Normal, None);
        s.admit(2, Priority::Normal, None);
        // max_b 1 disables batching even for decode-ready entries.
        assert_eq!(s.pick_batch(1, &[true, true]), vec![0]);
        assert_eq!(s.pick_batch(1, &[true, true]), vec![1]);
        // A lone decode-ready entry forms a batch of one.
        let mut s = StepScheduler::new();
        s.admit(3, Priority::Normal, None);
        assert_eq!(s.pick_batch(8, &[true]), vec![0]);
        assert_eq!(s.entry(0).steps, 1);
    }

    #[test]
    fn pick_batch_empty_scheduler() {
        let mut s = StepScheduler::new();
        assert!(s.pick_batch(8, &[]).is_empty());
    }

    #[test]
    fn pick_batch_classed_drains_only_compatible_entries() {
        let mut s = StepScheduler::new();
        for id in 0..4 {
            s.admit(id, Priority::Normal, None);
        }
        let ready = vec![true; 4];
        // Entries 0, 2 share class 7; entries 1, 3 share class 9.
        let classes = vec![7u64, 9, 7, 9];
        let picked = s.pick_batch_classed(8, &ready, &classes);
        assert_eq!(picked, vec![0, 2], "only the primary's class fuses");
        // Next quantum starts at entry 1: the other class fuses then.
        let picked = s.pick_batch_classed(8, &ready, &classes);
        assert_eq!(picked, vec![1, 3]);
        assert_eq!(s.max_step_gap(), 0, "classes alternate without starvation");
        // Cursor is now at entry 2: its class (7) fuses with entry 0,
        // wrapping, and never with the 8/9 singletons.
        let classes = vec![7u64, 8, 7, 9];
        let picked = s.pick_batch_classed(8, &ready, &classes);
        assert_eq!(picked, vec![0, 2]);
        // An empty classes slice means one shared class — the legacy
        // pick_batch behavior drains everyone.
        assert_eq!(s.pick_batch(8, &ready), vec![0, 1, 2, 3]);
    }

    #[test]
    fn pick_batch_gated_never_grants_blocked_entries() {
        let mut s = StepScheduler::new();
        for id in 0..4 {
            s.admit(id, Priority::Normal, None);
        }
        let ready = vec![true; 4];
        // Entry 1 parked: fused batch drains around it.
        let blocked = vec![false, true, false, false];
        let picked = s.pick_batch_gated(8, &ready, &[], &blocked);
        assert_eq!(picked, vec![0, 2, 3]);
        assert_eq!(s.entry(1).steps, 0, "parked entry never stepped");
        // Cursor landed on the parked entry: it is skipped (no quantum,
        // no step charge), and the batch re-forms from entry 2.
        let picked = s.pick_batch_gated(8, &ready, &[], &blocked);
        assert_eq!(picked, vec![0, 2, 3]);
        assert_eq!(s.entry(1).steps, 0);
    }

    #[test]
    fn pick_batch_gated_blocks_prefill_fallback_too() {
        let mut s = StepScheduler::new();
        s.admit(1, Priority::Normal, None);
        s.admit(2, Priority::Normal, None);
        // Cursor entry is parked *and* not decode-ready: without the
        // gate this would degrade to pick() and step it anyway.
        let ready = vec![false, true];
        let blocked = vec![true, false];
        let picked = s.pick_batch_gated(8, &ready, &[], &blocked);
        assert_eq!(picked, vec![1]);
        assert_eq!(s.entry(0).steps, 0, "parked prefill entry not stepped");
    }

    #[test]
    fn pick_batch_gated_all_blocked_is_empty_and_position_survives() {
        let mut s = StepScheduler::new();
        for id in 0..3 {
            s.admit(id, Priority::Normal, None);
        }
        let ready = vec![true; 3];
        assert_eq!(s.pick_batch_gated(1, &ready, &[], &[false; 3]), vec![0]);
        // Everyone parked: no quantum, nobody charged.
        assert!(s.pick_batch_gated(8, &ready, &[], &[true; 3]).is_empty());
        assert_eq!(s.total_steps(), 1);
        // Unpark: rotation resumes from where it left off.
        assert_eq!(s.pick_batch_gated(1, &ready, &[], &[false; 3]), vec![1]);
    }

    #[test]
    fn pick_batch_gated_respects_classes_among_runnable() {
        let mut s = StepScheduler::new();
        for id in 0..4 {
            s.admit(id, Priority::Normal, None);
        }
        let ready = vec![true; 4];
        let classes = vec![7u64, 7, 9, 7];
        // Primary (0) fuses class 7, minus the parked batchmate (1).
        let blocked = vec![false, true, false, false];
        let picked = s.pick_batch_gated(8, &ready, &classes, &blocked);
        assert_eq!(picked, vec![0, 3]);
    }

    #[test]
    fn expired_entries_found() {
        let mut s = StepScheduler::new();
        let now = Instant::now();
        s.admit(1, Priority::Normal, None);
        s.admit(2, Priority::Normal, Some(now));
        assert_eq!(s.first_expired(now), Some(1));
        s.remove(1);
        assert_eq!(s.first_expired(now), None);
    }
}
