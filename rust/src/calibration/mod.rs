//! Offline rollout calibration (paper §2.2 "Global pruning").
//!
//! The paper derives its runtime policy by applying an attention-rollout
//! threshold at the middle layer over ~100 non-test samples: tokens whose
//! accumulated influence on the final query falls below the threshold are
//! "less informative", and their positions turn out to be a *positional*
//! rule (beyond position ~750 for VideoLLaMA2; beyond frame 4 for
//! video-SALMONN2). This module reproduces that pipeline:
//!
//! 1. run [`ModelEngine::calib_probe`] on N calibration samples,
//! 2. average the rollout influence of each AV position on the last query
//!    at the middle layer,
//! 3. threshold → per-modality positional keep rule
//!    (`vis_cutoff`, `keep_audio`, `keep_frames`),
//! 4. persist as `calibration.json` for the serving path.

use std::path::Path;

use anyhow::{anyhow, Context, Result};

use crate::avsynth::{gen_sample, Dataset};
use crate::model::{ModelEngine, PruningPlan};
use crate::pruning::{FineStrategy, GlobalStrategy};
use crate::tokens::Segment;
use crate::util::json::Json;

/// Calibrated positional pruning rule + the evidence that produced it.
#[derive(Debug, Clone, PartialEq)]
pub struct Calibration {
    pub model: String,
    pub samples: usize,
    pub threshold: f32,
    /// Keep visual tokens with original position `< vis_cutoff` (sequential
    /// layouts).
    pub vis_cutoff: usize,
    /// Keep the first N audio tokens (sequential layouts).
    pub keep_audio: usize,
    /// Keep the first F whole frames (interleaved layouts).
    pub keep_frames: usize,
    /// AV tokens kept by the rule (the FLOPs-matched ablation budget).
    pub budget: usize,
    /// Mean rollout influence per prompt position (AV prefix only).
    pub profile: Vec<f32>,
}

impl Calibration {
    /// FastAV serving plan at fine-pruning ratio `p` (paper: P = 20).
    pub fn plan(&self, p: f64) -> PruningPlan {
        let mut plan =
            PruningPlan::fastav(self.vis_cutoff, self.keep_audio, self.keep_frames, p);
        plan.global_budget = self.budget;
        plan
    }

    /// Global-only plan (Table 2 rows / Table 4 row P=0).
    pub fn global_only_plan(&self) -> PruningPlan {
        let mut plan = self.plan(0.0);
        plan.fine = FineStrategy::None;
        plan
    }

    /// Budget-matched ablation plan with a different global strategy.
    pub fn ablation_plan(&self, strategy: GlobalStrategy, fine: FineStrategy, p: f64) -> PruningPlan {
        PruningPlan {
            global: strategy,
            global_budget: self.budget,
            fine,
            fine_percent: p,
            ..PruningPlan::vanilla()
        }
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("model", Json::str(&self.model)),
            ("samples", Json::num(self.samples as f64)),
            ("threshold", Json::num(self.threshold as f64)),
            ("vis_cutoff", Json::num(self.vis_cutoff as f64)),
            ("keep_audio", Json::num(self.keep_audio as f64)),
            ("keep_frames", Json::num(self.keep_frames as f64)),
            ("budget", Json::num(self.budget as f64)),
            (
                "profile",
                Json::arr(self.profile.iter().map(|&v| Json::num(v as f64))),
            ),
        ])
    }

    pub fn from_json(j: &Json) -> Result<Calibration> {
        Ok(Calibration {
            model: j.get("model").as_str().ok_or_else(|| anyhow!("model"))?.to_string(),
            samples: j.get("samples").as_usize().ok_or_else(|| anyhow!("samples"))?,
            threshold: j.get("threshold").as_f64().ok_or_else(|| anyhow!("threshold"))? as f32,
            vis_cutoff: j.get("vis_cutoff").as_usize().ok_or_else(|| anyhow!("vis_cutoff"))?,
            keep_audio: j.get("keep_audio").as_usize().ok_or_else(|| anyhow!("keep_audio"))?,
            keep_frames: j.get("keep_frames").as_usize().ok_or_else(|| anyhow!("keep_frames"))?,
            budget: j.get("budget").as_usize().ok_or_else(|| anyhow!("budget"))?,
            profile: j
                .get("profile")
                .as_arr()
                .ok_or_else(|| anyhow!("profile"))?
                .iter()
                .map(|v| v.as_f64().unwrap_or(0.0) as f32)
                .collect(),
        })
    }

    pub fn save(&self, path: &Path) -> Result<()> {
        std::fs::write(path, self.to_json().to_string())
            .with_context(|| format!("writing {:?}", path))
    }

    pub fn load(path: &Path) -> Result<Calibration> {
        let text = std::fs::read_to_string(path).with_context(|| format!("reading {:?}", path))?;
        Calibration::from_json(&Json::parse(&text).map_err(|e| anyhow!("{}", e))?)
    }
}

/// Fraction of each modality's rollout influence the positional rule must
/// cover. The paper applies "an attention rollout threshold"; a coverage
/// target is the parameter-light equivalent that adapts to the influence
/// distribution instead of its mean (mean thresholds over-prune when the
/// profile has a long flat tail).
pub const COVERAGE: f32 = 0.90;

/// Pure rule derivation from an influence profile (unit-testable core).
///
/// Keeps the shortest per-modality *prefix* covering [`COVERAGE`] of that
/// modality's total rollout influence on the final query: visual tokens up
/// to `vis_cutoff`, the first `keep_audio` audio slots, and (interleaved
/// layouts) the first `keep_frames` frames. The reported `threshold` is
/// the influence at the visual cutoff boundary (diagnostic only).
pub fn derive_rule(
    profile: &[f32],
    segments: &[Segment],
    frame_of: &[i32],
    interleaved: bool,
) -> (f32, usize, usize, usize, usize) {
    let av: Vec<usize> = (0..profile.len())
        .filter(|&i| matches!(segments[i], Segment::Vis | Segment::Aud))
        .collect();
    assert!(!av.is_empty());

    // Shortest prefix of `items` whose influence sum reaches COVERAGE of
    // the total; returns the prefix length.
    let prefix_cover = |items: &[usize]| -> usize {
        let total: f32 = items.iter().map(|&i| profile[i]).sum();
        if total <= 0.0 {
            return items.len();
        }
        let mut acc = 0.0f32;
        for (rank, &i) in items.iter().enumerate() {
            acc += profile[i];
            if acc >= COVERAGE * total {
                return rank + 1;
            }
        }
        items.len()
    };

    let vis: Vec<usize> = av.iter().copied().filter(|&i| segments[i] == Segment::Vis).collect();
    let aud: Vec<usize> = av.iter().copied().filter(|&i| segments[i] == Segment::Aud).collect();

    let (mut vis_cutoff, mut keep_audio) = (0usize, 0usize);
    let mut threshold = 0.0f32;
    if !interleaved {
        if !vis.is_empty() {
            let n_keep = prefix_cover(&vis);
            vis_cutoff = vis[n_keep - 1] + 1;
            threshold = profile[vis[n_keep - 1]];
        }
        if !aud.is_empty() {
            keep_audio = prefix_cover(&aud).max(1);
        }
    }

    // Interleaved rule: shortest frame prefix covering COVERAGE of the
    // total per-frame influence.
    let mut keep_frames = 0usize;
    if interleaved {
        let max_frame = frame_of.iter().copied().max().unwrap_or(-1);
        let mut frame_mass = Vec::new();
        for f in 0..=max_frame.max(0) {
            let m: f32 = av
                .iter()
                .copied()
                .filter(|&i| frame_of[i] == f)
                .map(|i| profile[i])
                .sum();
            frame_mass.push(m);
        }
        let total: f32 = frame_mass.iter().sum();
        let mut acc = 0.0f32;
        for (f, &m) in frame_mass.iter().enumerate() {
            acc += m;
            keep_frames = f + 1;
            if total > 0.0 && acc >= COVERAGE * total {
                break;
            }
        }
        keep_frames = keep_frames.max(1);
    }

    // Budget = AV tokens the rule keeps.
    let mut budget = 0usize;
    for &i in &av {
        let kept = if interleaved {
            (frame_of[i] as usize) < keep_frames && frame_of[i] >= 0
        } else {
            match segments[i] {
                Segment::Vis => i < vis_cutoff,
                Segment::Aud => {
                    let audio_rank = av
                        .iter()
                        .filter(|&&j| segments[j] == Segment::Aud && j < i)
                        .count();
                    audio_rank < keep_audio
                }
                _ => false,
            }
        };
        if kept {
            budget += 1;
        }
    }
    (threshold, vis_cutoff, keep_audio, keep_frames, budget)
}

/// Run the full calibration pipeline over `n_samples` calib-stream samples.
pub fn calibrate(engine: &mut ModelEngine, n_samples: usize, base_seed: u64) -> Result<Calibration> {
    let layout = engine.cfg.layout.clone();
    let mid = engine.cfg.mid_layer;
    // AV prefix length is layout-stable; text tail varies per question.
    let av_prefix = 1 + layout.vis_tokens() + layout.audio_tokens();
    let mut sums = vec![0.0f64; av_prefix];
    let mut reference: Option<(Vec<Segment>, Vec<i32>)> = None;

    for i in 0..n_samples {
        let s = gen_sample(&layout, Dataset::Calib, i as u64, base_seed);
        let probe = engine.calib_probe(&s.prompt)?;
        let row = probe.last_row(mid);
        for (p, &v) in row.iter().take(av_prefix).enumerate() {
            sums[p] += v as f64;
        }
        if reference.is_none() {
            reference = Some((
                s.segments[..av_prefix].to_vec(),
                s.frame_of[..av_prefix].to_vec(),
            ));
        }
    }
    let profile: Vec<f32> = sums.iter().map(|&s| (s / n_samples as f64) as f32).collect();
    let (segments, frame_of) = reference.ok_or_else(|| anyhow!("no calib samples"))?;
    let (threshold, vis_cutoff, keep_audio, keep_frames, budget) =
        derive_rule(&profile, &segments, &frame_of, layout.interleaved);
    Ok(Calibration {
        model: engine.cfg.name.clone(),
        samples: n_samples,
        threshold,
        vis_cutoff,
        keep_audio,
        keep_frames,
        budget,
        profile,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Sequential toy: BOS + 6 vis + 4 aud; early tokens influential.
    fn toy() -> (Vec<f32>, Vec<Segment>, Vec<i32>) {
        let mut segments = vec![Segment::Ctrl];
        let mut frames = vec![-1];
        for f in 0..3 {
            segments.push(Segment::Vis);
            frames.push(f);
            segments.push(Segment::Vis);
            frames.push(f);
        }
        for _ in 0..4 {
            segments.push(Segment::Aud);
            frames.push(-1);
        }
        // Influence: high on BOS + first 3 vis + first 2 aud.
        let profile = vec![
            0.5, // BOS (not AV; ignored by the rule)
            0.3, 0.25, 0.2, 0.01, 0.01, 0.01, // vis
            0.2, 0.15, 0.01, 0.01, // aud
        ];
        (profile, segments, frames)
    }

    #[test]
    fn derive_rule_sequential_covers_mass() {
        let (profile, segments, frames) = toy();
        let (th, vis_cutoff, keep_audio, keep_frames, budget) =
            derive_rule(&profile, &segments, &frames, false);
        assert!(th > 0.0);
        // Vis influence: [.3, .25, .2, .01, .01, .01] (total .78); 90%
        // coverage (.702) is reached at prefix sum .75 (3 positions) ->
        // cutoff = position 3 + 1 = 4.
        assert_eq!(vis_cutoff, 4);
        // Audio influence: [.2, .15, .01, .01] (total .37); 90% (.333) is
        // reached at prefix sum .35 (2 slots).
        assert_eq!(keep_audio, 2);
        assert_eq!(keep_frames, 0);
        assert_eq!(budget, 3 + 2);
    }

    #[test]
    fn derive_rule_sequential_tail_excluded() {
        // All mass on the first vis token: cutoff collapses to 2.
        let segments = vec![Segment::Ctrl, Segment::Vis, Segment::Vis, Segment::Vis, Segment::Aud];
        let frames = vec![-1, 0, 0, 0, -1];
        let profile = vec![0.5, 1.0, 0.0, 0.0, 0.2];
        let (_th, vis_cutoff, keep_audio, _kf, budget) =
            derive_rule(&profile, &segments, &frames, false);
        assert_eq!(vis_cutoff, 2);
        assert_eq!(keep_audio, 1);
        assert_eq!(budget, 2);
    }

    #[test]
    fn derive_rule_interleaved() {
        // 2 frames, each (vis, vis, aud); frame 0 hot, frame 1 cold.
        let segments = vec![
            Segment::Ctrl,
            Segment::Vis,
            Segment::Vis,
            Segment::Aud,
            Segment::Vis,
            Segment::Vis,
            Segment::Aud,
        ];
        let frames = vec![-1, 0, 0, 0, 1, 1, 1];
        let profile = vec![0.4, 0.3, 0.3, 0.3, 0.01, 0.01, 0.01];
        let (_th, _vc, _ka, keep_frames, budget) =
            derive_rule(&profile, &segments, &frames, true);
        assert_eq!(keep_frames, 1);
        assert_eq!(budget, 3);
    }

    #[test]
    fn json_roundtrip() {
        let c = Calibration {
            model: "vl2sim".into(),
            samples: 100,
            threshold: 0.01,
            vis_cutoff: 20,
            keep_audio: 4,
            keep_frames: 0,
            budget: 23,
            profile: vec![0.1, 0.2, 0.3],
        };
        let j = c.to_json();
        let back = Calibration::from_json(&Json::parse(&j.to_string()).unwrap()).unwrap();
        assert_eq!(c, back);
    }

    #[test]
    fn plan_carries_budget() {
        let c = Calibration {
            model: "m".into(),
            samples: 1,
            threshold: 0.0,
            vis_cutoff: 10,
            keep_audio: 4,
            keep_frames: 2,
            budget: 14,
            profile: vec![],
        };
        let p = c.plan(20.0);
        assert_eq!(p.global_budget, 14);
        assert!(matches!(p.global, GlobalStrategy::FastAvPosition { vis_cutoff: 10, keep_audio: 4, keep_frames: 2 }));
        let g = c.global_only_plan();
        assert_eq!(g.fine, FineStrategy::None);
    }
}
