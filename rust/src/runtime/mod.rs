//! PJRT runtime: loads AOT HLO-text artifacts and executes them.
//!
//! Wraps the `xla` crate (PJRT C API, CPU client). All execution happens
//! on the thread that owns [`Runtime`] — PJRT handles are not `Send` in
//! this crate, so each mesh device pins its `Runtime` to a persistent
//! [`worker`] thread and ships work to it over a command queue.
//!
//! Pieces:
//! * [`Runtime`]     — client + executable cache (compile each HLO once).
//! * [`worker`]      — persistent per-device worker threads: FIFO
//!   command queue, panic-isolating job execution, non-blocking
//!   submission (the hook the pipelined engine overlaps uploads on).
//! * [`mesh`]        — the [`Backend`]/[`DeviceMesh`] abstraction: D
//!   logical devices behind one dispatch surface (tensor-parallel
//!   head-sharded execution; device 0 is the `tp_degree = 1` case).
//! * [`ArtifactDir`] — artifact discovery + *bucket selection*: artifacts
//!   are compiled at fixed sequence lengths; `pick_bucket(n)` returns the
//!   smallest compiled bucket that fits.
//! * [`literals`]    — typed host↔literal conversion helpers.

pub mod literals;
pub mod mesh;
pub mod worker;

pub use mesh::{Backend, DeviceMesh, ShardDispatch};
pub use worker::{DeviceWorker, JobOutcome};

use std::collections::{BTreeMap, HashMap};
use std::path::{Path, PathBuf};

use anyhow::{anyhow, bail, Context, Result};

/// Artifact directory for one model (e.g. `artifacts/vl2sim/`).
#[derive(Debug, Clone)]
pub struct ArtifactDir {
    root: PathBuf,
    /// entry name -> sorted bucket list (empty vec for unbucketed entries).
    buckets: BTreeMap<String, Vec<usize>>,
}

impl ArtifactDir {
    /// Scan `root` for `<entry>_<n>.hlo.txt` / `<entry>.hlo.txt` files.
    pub fn open(root: impl AsRef<Path>) -> Result<ArtifactDir> {
        let root = root.as_ref().to_path_buf();
        let mut buckets: BTreeMap<String, Vec<usize>> = BTreeMap::new();
        let entries = std::fs::read_dir(&root)
            .with_context(|| format!("artifact dir {:?} (run `make artifacts`)", root))?;
        for e in entries {
            let name = e?.file_name().to_string_lossy().into_owned();
            let Some(stem) = name.strip_suffix(".hlo.txt") else { continue };
            // Split a trailing _<number> if present.
            match stem.rsplit_once('_') {
                Some((base, num)) if num.chars().all(|c| c.is_ascii_digit()) => {
                    buckets
                        .entry(base.to_string())
                        .or_default()
                        .push(num.parse().unwrap());
                }
                _ => {
                    buckets.entry(stem.to_string()).or_default();
                }
            }
        }
        for v in buckets.values_mut() {
            v.sort_unstable();
        }
        if buckets.is_empty() {
            bail!("no .hlo.txt artifacts in {:?}", root);
        }
        Ok(ArtifactDir { root, buckets })
    }

    pub fn root(&self) -> &Path {
        &self.root
    }

    /// Smallest compiled bucket with capacity >= `needed`.
    pub fn pick_bucket(&self, entry: &str, needed: usize) -> Result<usize> {
        let buckets = self
            .buckets
            .get(entry)
            .ok_or_else(|| anyhow!("unknown artifact entry '{}'", entry))?;
        buckets
            .iter()
            .copied()
            .find(|&b| b >= needed)
            .ok_or_else(|| {
                anyhow!(
                    "no bucket >= {} for entry '{}' (have {:?})",
                    needed,
                    entry,
                    buckets
                )
            })
    }

    pub fn buckets(&self, entry: &str) -> &[usize] {
        self.buckets.get(entry).map(|v| v.as_slice()).unwrap_or(&[])
    }

    /// Path of a (possibly bucketed) artifact.
    pub fn path(&self, entry: &str, bucket: Option<usize>) -> PathBuf {
        match bucket {
            Some(n) => self.root.join(format!("{}_{}.hlo.txt", entry, n)),
            None => self.root.join(format!("{}.hlo.txt", entry)),
        }
    }

    pub fn has_entry(&self, entry: &str) -> bool {
        self.buckets.contains_key(entry)
    }
}

/// PJRT client + executable cache. One per engine thread.
pub struct Runtime {
    client: xla::PjRtClient,
    cache: HashMap<PathBuf, xla::PjRtLoadedExecutable>,
    pub compile_count: usize,
    pub exec_count: u64,
}

impl Runtime {
    pub fn cpu() -> Result<Runtime> {
        let client = xla::PjRtClient::cpu().map_err(|e| anyhow!("pjrt cpu client: {:?}", e))?;
        Ok(Runtime { client, cache: HashMap::new(), compile_count: 0, exec_count: 0 })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Load + compile an HLO-text artifact (cached by path).
    pub fn load(&mut self, path: &Path) -> Result<()> {
        if self.cache.contains_key(path) {
            return Ok(());
        }
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().ok_or_else(|| anyhow!("non-utf8 path"))?,
        )
        .map_err(|e| anyhow!("parse {:?}: {:?}", path, e))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| anyhow!("compile {:?}: {:?}", path, e))?;
        self.cache.insert(path.to_path_buf(), exe);
        self.compile_count += 1;
        Ok(())
    }

    /// Execute a previously loaded artifact. Inputs are borrowed literals;
    /// the (single, tuple-typed) output is decomposed into its elements.
    pub fn execute(&mut self, path: &Path, inputs: &[&xla::Literal]) -> Result<Vec<xla::Literal>> {
        self.load(path)?;
        let exe = self.cache.get(path).unwrap();
        let out = exe
            .execute::<&xla::Literal>(inputs)
            .map_err(|e| anyhow!("execute {:?}: {:?}", path, e))?;
        self.exec_count += 1;
        let lit = out[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("fetch result {:?}: {:?}", path, e))?;
        lit.to_tuple().map_err(|e| anyhow!("untuple {:?}: {:?}", path, e))
    }

    /// Number of compiled executables held.
    pub fn cached(&self) -> usize {
        self.cache.len()
    }

    /// Upload a literal to a device-resident buffer (perf path: weights go
    /// up once at startup instead of once per execution).
    pub fn to_buffer(&self, lit: &xla::Literal) -> Result<xla::PjRtBuffer> {
        self.client
            .buffer_from_host_literal(None, lit)
            .map_err(|e| anyhow!("buffer_from_host_literal: {:?}", e))
    }

    /// Execute with device-resident buffers (mixed activation/weight
    /// inputs; the caller pre-uploads everything).
    pub fn execute_buffers(
        &mut self,
        path: &Path,
        inputs: &[&xla::PjRtBuffer],
    ) -> Result<Vec<xla::Literal>> {
        self.load(path)?;
        let exe = self.cache.get(path).unwrap();
        let out = exe
            .execute_b::<&xla::PjRtBuffer>(inputs)
            .map_err(|e| anyhow!("execute_b {:?}: {:?}", path, e))?;
        self.exec_count += 1;
        let lit = out[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("fetch result {:?}: {:?}", path, e))?;
        lit.to_tuple().map_err(|e| anyhow!("untuple {:?}: {:?}", path, e))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Write;

    /// Minimal tempdir helper (no tempfile crate on this image).
    struct TempDir(PathBuf);

    impl Drop for TempDir {
        fn drop(&mut self) {
            let _ = std::fs::remove_dir_all(&self.0);
        }
    }

    fn fake_dir(tag: &str, files: &[&str]) -> TempDir {
        let dir = std::env::temp_dir().join(format!("fastav-test-{}-{}", std::process::id(), tag));
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        for f in files {
            let mut fh = std::fs::File::create(dir.join(f)).unwrap();
            writeln!(fh, "HloModule placeholder").unwrap();
        }
        TempDir(dir)
    }

    #[test]
    fn scans_entries_and_buckets() {
        let d = fake_dir(
            "scan",
            &[
                "prefill_front_128.hlo.txt",
                "back_layer_32.hlo.txt",
                "back_layer_64.hlo.txt",
                "back_layer_128.hlo.txt",
                "logits.hlo.txt",
                "model.json",
            ],
        );
        let a = ArtifactDir::open(&d.0).unwrap();
        assert_eq!(a.buckets("back_layer"), &[32, 64, 128]);
        assert_eq!(a.buckets("prefill_front"), &[128]);
        assert!(a.has_entry("logits"));
        assert!(!a.has_entry("nope"));
    }

    #[test]
    fn bucket_selection_smallest_fit() {
        let d = fake_dir(
            "buckets",
            &[
                "back_layer_32.hlo.txt",
                "back_layer_64.hlo.txt",
                "back_layer_128.hlo.txt",
            ],
        );
        let a = ArtifactDir::open(&d.0).unwrap();
        assert_eq!(a.pick_bucket("back_layer", 1).unwrap(), 32);
        assert_eq!(a.pick_bucket("back_layer", 32).unwrap(), 32);
        assert_eq!(a.pick_bucket("back_layer", 33).unwrap(), 64);
        assert_eq!(a.pick_bucket("back_layer", 128).unwrap(), 128);
        assert!(a.pick_bucket("back_layer", 129).is_err());
        assert!(a.pick_bucket("missing", 1).is_err());
    }

    #[test]
    fn missing_dir_errors() {
        assert!(ArtifactDir::open("/nonexistent/fastav").is_err());
    }

    #[test]
    fn artifact_paths() {
        let d = fake_dir("paths", &["decode_layer_32.hlo.txt"]);
        let a = ArtifactDir::open(&d.0).unwrap();
        assert!(a
            .path("decode_layer", Some(32))
            .to_string_lossy()
            .ends_with("decode_layer_32.hlo.txt"));
        assert!(a.path("logits", None).to_string_lossy().ends_with("logits.hlo.txt"));
    }
}
