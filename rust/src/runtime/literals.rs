//! Host ↔ XLA literal conversion helpers.
//!
//! All artifact inputs/outputs are f32 tensors or i32 scalars/vectors;
//! these helpers centralize the (unsafe-ish) byte-level conversions so the
//! engine code stays shape-explicit and checked.

use anyhow::{anyhow, Result};
use xla::{ElementType, Literal};

/// f32 tensor literal from a host slice (row-major).
pub fn lit_f32(dims: &[usize], data: &[f32]) -> Result<Literal> {
    let expect: usize = dims.iter().product();
    if expect != data.len() {
        return Err(anyhow!("lit_f32 shape {:?} wants {} elems, got {}", dims, expect, data.len()));
    }
    let bytes = unsafe {
        std::slice::from_raw_parts(data.as_ptr() as *const u8, data.len() * 4)
    };
    Literal::create_from_shape_and_untyped_data(ElementType::F32, dims, bytes)
        .map_err(|e| anyhow!("create f32 literal: {:?}", e))
}

/// i32 tensor literal.
pub fn lit_i32(dims: &[usize], data: &[i32]) -> Result<Literal> {
    let expect: usize = dims.iter().product();
    if expect != data.len() {
        return Err(anyhow!("lit_i32 shape {:?} wants {} elems, got {}", dims, expect, data.len()));
    }
    let bytes = unsafe {
        std::slice::from_raw_parts(data.as_ptr() as *const u8, data.len() * 4)
    };
    Literal::create_from_shape_and_untyped_data(ElementType::S32, dims, bytes)
        .map_err(|e| anyhow!("create i32 literal: {:?}", e))
}

/// i32 scalar literal.
pub fn lit_i32_scalar(v: i32) -> Result<Literal> {
    lit_i32(&[], &[v])
}

/// Copy a literal's f32 payload to a host Vec.
pub fn to_vec_f32(lit: &Literal) -> Result<Vec<f32>> {
    lit.to_vec::<f32>().map_err(|e| anyhow!("literal to f32 vec: {:?}", e))
}

/// Copy a literal's f32 payload into an existing buffer (hot path: avoids
/// a fresh allocation per decode step).
pub fn copy_f32_into(lit: &Literal, dst: &mut [f32]) -> Result<()> {
    if lit.element_count() != dst.len() {
        return Err(anyhow!(
            "copy_f32_into: literal has {} elems, dst {}",
            lit.element_count(),
            dst.len()
        ));
    }
    lit.copy_raw_to::<f32>(dst).map_err(|e| anyhow!("copy_raw_to: {:?}", e))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn f32_roundtrip() {
        let data: Vec<f32> = (0..12).map(|i| i as f32).collect();
        let lit = lit_f32(&[3, 4], &data).unwrap();
        assert_eq!(lit.element_count(), 12);
        assert_eq!(to_vec_f32(&lit).unwrap(), data);
    }

    #[test]
    fn i32_roundtrip() {
        let data = vec![5i32, -3, 7];
        let lit = lit_i32(&[3], &data).unwrap();
        assert_eq!(lit.to_vec::<i32>().unwrap(), data);
    }

    #[test]
    fn scalar() {
        let lit = lit_i32_scalar(42).unwrap();
        assert_eq!(lit.element_count(), 1);
        assert_eq!(lit.get_first_element::<i32>().unwrap(), 42);
    }

    #[test]
    fn shape_mismatch_errors() {
        assert!(lit_f32(&[2, 2], &[1.0]).is_err());
        assert!(lit_i32(&[3], &[1, 2]).is_err());
    }

    #[test]
    fn copy_into_checks_len() {
        let lit = lit_f32(&[4], &[1.0, 2.0, 3.0, 4.0]).unwrap();
        let mut buf = vec![0.0f32; 4];
        copy_f32_into(&lit, &mut buf).unwrap();
        assert_eq!(buf, vec![1.0, 2.0, 3.0, 4.0]);
        let mut short = vec![0.0f32; 3];
        assert!(copy_f32_into(&lit, &mut short).is_err());
    }
}
