//! Device-mesh execution backend: D logical PJRT devices behind one
//! dispatch surface.
//!
//! A [`DeviceMesh`] owns one persistent [`DeviceWorker`] (thread +
//! `Runtime`: client + executable cache) per logical device.
//! Single-device work (`tp_degree = 1`, replicated artifacts like
//! `calib_probe`, combine/`*_tail` stages) runs on device 0's worker
//! through [`DeviceMesh::execute`]. Head-sharded work fans one
//! [`ShardDispatch`] per device through
//! [`DeviceMesh::execute_sharded`]: every shard is enqueued on its
//! worker's command queue, then the call receives every completion
//! before returning (an all-or-nothing barrier — the combine step
//! needs every partial).
//!
//! Persistent workers replaced the original scoped-thread fan-out
//! (which spawned + joined one OS thread per remote shard *per
//! dispatch*): each device's `Runtime` now stays pinned to its
//! long-lived worker so the executable cache is warm with zero
//! per-dispatch thread churn, and — because submission is decoupled
//! from completion — the engine can overlap host-side work with an
//! in-flight dispatch ([`DeviceMesh::execute_queued`] returns a
//! [`Pending`] handle the pipelined batched-decode loop waits on after
//! staging the next layer's upload). Input literals are *borrowed* by
//! an in-flight job via a raw-pointer `Send` shim; safety rests on one
//! invariant, enforced structurally below: **every enqueued job is
//! received before the borrow that produced its inputs ends**
//! (`Pending::wait`, `Pending`'s drop drain, and the
//! enqueue-all-then-receive-all shape of `execute_sharded`).
//!
//! Panic parity with the scoped-thread era is preserved for the
//! supervision layer: a shard-0 (or device-0) panic is re-raised on
//! the calling replica thread after the join barrier — exactly as when
//! shard 0 ran on the caller — so replica guards still poison and
//! respawn; a remote shard's panic fails only that dispatch with shard
//! attribution, and the worker (plus its compiled-executable cache)
//! survives for the next quantum.

use std::any::Any;
use std::marker::PhantomData;
use std::panic::resume_unwind;
use std::path::{Path, PathBuf};
use std::sync::mpsc;

use anyhow::{anyhow, bail, Context, Result};

use super::worker::{DeviceWorker, JobOutcome};

/// One shard's work item: the artifact to run on that device and its
/// borrowed input literals (activations + that shard's weight slices).
pub struct ShardDispatch<'a> {
    pub path: PathBuf,
    pub inputs: Vec<&'a xla::Literal>,
}

/// The execution surface the engine drives, named so an alternative
/// backend (a real multi-device PJRT client, a remote executor) has a
/// contract to implement. [`DeviceMesh`] is the only implementor today
/// and the engine holds it concretely — `execute`/`execute_sharded` are
/// inherent methods (the trait impl delegates), so callers need no
/// trait import.
pub trait Backend {
    /// Logical devices in the mesh (the tensor-parallel degree).
    fn device_count(&self) -> usize;

    /// Run a replicated artifact on device 0.
    fn execute(&mut self, path: &Path, inputs: &[&xla::Literal]) -> Result<Vec<xla::Literal>>;

    /// Run `dispatches[s]` on device `s` (one per device, in parallel)
    /// and return every shard's outputs in device order.
    fn execute_sharded(&mut self, dispatches: &[ShardDispatch<'_>])
        -> Result<Vec<Vec<xla::Literal>>>;
}

/// What a worker sends back for one dispatch: the execution result and,
/// when the quantum is traced, the dispatch interval measured on the
/// worker (it cannot see the caller's thread-local segment collector,
/// so it carries a clone of the trace clock instead).
type DispatchReply = (Result<Vec<xla::Literal>>, Option<(u64, u64)>);

/// `*const xla::Literal` that crosses the worker channel. SAFETY
/// invariant (upheld by every call site in this module): the pointed-to
/// literal outlives the job, because the submitting code always
/// receives the job's completion before the borrow producing the
/// pointer ends.
struct SendLit(*const xla::Literal);
unsafe impl Send for SendLit {}

/// An in-flight device-0 dispatch returned by
/// [`DeviceMesh::execute_queued`]. Holds the lifetime of the input
/// literals, so the borrow checker pins them until the dispatch is
/// waited on — and if the handle is dropped early (error unwinding in
/// the caller), the drop impl blocks until the worker has released
/// them.
pub struct Pending<'a> {
    rx: mpsc::Receiver<JobOutcome<DispatchReply>>,
    /// `Some(shard)` records a "dispatch" trace segment on completion;
    /// `None` keeps plain `execute` trace-silent (its callers time
    /// themselves, as they always have).
    seg_shard: Option<u32>,
    waited: bool,
    _borrow: PhantomData<&'a xla::Literal>,
}

impl Pending<'_> {
    /// Block until the dispatch completes and return its outputs. A
    /// panic inside the worker job is re-raised here, on the calling
    /// thread.
    pub fn wait(mut self) -> Result<Vec<xla::Literal>> {
        self.waited = true;
        match self.rx.recv() {
            Ok(JobOutcome::Done((r, interval))) => {
                if let (Some(s), Some((t0, t1))) = (self.seg_shard, interval) {
                    crate::trace::push_seg("dispatch", Some(s), t0, t1);
                }
                r
            }
            Ok(JobOutcome::Panicked(p)) => resume_unwind(p),
            Err(_) => Err(anyhow!("device worker died before completing the dispatch")),
        }
    }
}

impl Drop for Pending<'_> {
    fn drop(&mut self) {
        if !self.waited {
            // Block until the in-flight job has released the borrowed
            // input literals (the SendLit safety invariant). Receiving
            // a second time after `wait` would return immediately (the
            // sender is gone), so this is also harmlessly idempotent.
            let _ = self.rx.recv();
        }
    }
}

/// D logical devices, each a persistent worker thread owning its own
/// PJRT client + executable cache.
pub struct DeviceMesh {
    workers: Vec<DeviceWorker>,
}

impl DeviceMesh {
    /// A mesh of `tp` CPU devices (`tp = 0` is clamped to 1). Each
    /// device's worker thread (and its `Runtime`) is up before this
    /// returns.
    pub fn cpu(tp: usize) -> Result<DeviceMesh> {
        let workers = (0..tp.max(1))
            .map(|i| DeviceWorker::spawn(i).with_context(|| format!("mesh device {}", i)))
            .collect::<Result<Vec<_>>>()?;
        Ok(DeviceMesh { workers })
    }

    /// Tensor-parallel degree (number of devices).
    pub fn tp(&self) -> usize {
        self.workers.len()
    }

    pub fn platform(&self) -> String {
        self.workers[0]
            .call(|rt| rt.platform())
            .unwrap_or_else(|_| String::from("unknown"))
    }

    /// Pre-compile an artifact on device 0 (warmup of replicated and
    /// combine-stage entries).
    pub fn load(&mut self, path: &Path) -> Result<()> {
        self.load_on(0, path)
    }

    /// Pre-compile a per-shard artifact on its device (warmup).
    pub fn load_on(&mut self, device: usize, path: &Path) -> Result<()> {
        let path = path.to_path_buf();
        self.workers[device].call(move |rt| rt.load(&path))?
    }

    /// (compiled executables, total executions) summed over devices.
    pub fn stats(&self) -> (usize, u64) {
        self.workers.iter().fold((0, 0), |(c, e), w| {
            let (wc, we) = w.call(|rt| (rt.cached(), rt.exec_count)).unwrap_or((0, 0));
            (c + wc, e + we)
        })
    }

    /// Enqueue an artifact execution on `device`'s worker and return
    /// the completion receiver without blocking. The job borrows the
    /// input literals through `SendLit`; callers MUST receive the reply
    /// before those borrows end.
    fn enqueue(
        &self,
        device: usize,
        path: &Path,
        inputs: &[&xla::Literal],
    ) -> Result<mpsc::Receiver<JobOutcome<DispatchReply>>> {
        let clock = crate::trace::seg_clock();
        let path = path.to_path_buf();
        let lits: Vec<SendLit> = inputs.iter().map(|&l| SendLit(l as *const _)).collect();
        self.workers[device].submit_outcome(move |rt| {
            // SAFETY: see SendLit — the submitter keeps every input
            // literal alive until this job's reply is received.
            let refs: Vec<&xla::Literal> = lits.iter().map(|l| unsafe { &*l.0 }).collect();
            let t0 = clock.as_ref().map(|c| c.now_ns());
            let r = rt.execute(&path, &refs);
            let t1 = clock.as_ref().map(|c| c.now_ns());
            (r, t0.zip(t1))
        })
    }

    /// Run a replicated artifact on device 0 (blocking, same contract
    /// as the pre-worker mesh — records no trace segments of its own).
    pub fn execute(
        &mut self,
        path: &Path,
        inputs: &[&xla::Literal],
    ) -> Result<Vec<xla::Literal>> {
        let rx = self.enqueue(0, path, inputs)?;
        Pending { rx, seg_shard: None, waited: false, _borrow: PhantomData }.wait()
    }

    /// Enqueue a replicated artifact on device 0 and return a
    /// [`Pending`] handle instead of blocking — the hook the pipelined
    /// batched-decode loop uses to overlap the next layer's KV gather +
    /// literal build with this dispatch. The dispatch interval is
    /// recorded as a `dispatch` trace segment (shard 0) when waited on.
    pub fn execute_queued<'a>(
        &self,
        path: &Path,
        inputs: &[&'a xla::Literal],
    ) -> Result<Pending<'a>> {
        let rx = self.enqueue(0, path, inputs)?;
        Ok(Pending { rx, seg_shard: Some(0), waited: false, _borrow: PhantomData })
    }

    /// Run `dispatches[s]` on device `s` (one per device, in parallel)
    /// and return every shard's outputs in device order.
    pub fn execute_sharded(
        &mut self,
        dispatches: &[ShardDispatch<'_>],
    ) -> Result<Vec<Vec<xla::Literal>>> {
        if dispatches.len() != self.workers.len() {
            bail!(
                "sharded dispatch arity {} != mesh devices {}",
                dispatches.len(),
                self.workers.len()
            );
        }
        if dispatches.len() == 1 {
            let d = &dispatches[0];
            let t0 = crate::trace::seg_begin();
            let out = self.execute(&d.path, &d.inputs);
            crate::trace::seg_end("dispatch", Some(0), t0);
            return Ok(vec![out?]);
        }
        // Enqueue every shard, then receive every shard. No early
        // return between the two halves: a failed enqueue becomes an
        // Err entry and the receive loop still drains every receiver
        // that was created, so no worker is left holding a borrowed
        // input when this function returns (the SendLit invariant).
        enum Reply {
            Out(Result<Vec<xla::Literal>>),
            Panicked(Box<dyn Any + Send>),
        }
        let rxs: Vec<_> = dispatches
            .iter()
            .enumerate()
            .map(|(s, d)| self.enqueue(s, &d.path, &d.inputs))
            .collect();
        let replies: Vec<(Reply, Option<(u64, u64)>)> = rxs
            .into_iter()
            .map(|rx| match rx {
                Ok(rx) => match rx.recv() {
                    Ok(JobOutcome::Done((r, interval))) => (Reply::Out(r), interval),
                    Ok(JobOutcome::Panicked(p)) => (Reply::Panicked(p), None),
                    Err(_) => (
                        Reply::Out(Err(anyhow!(
                            "device worker died before completing the dispatch"
                        ))),
                        None,
                    ),
                },
                Err(e) => (Reply::Out(Err(e)), None),
            })
            .collect();
        // Traced quanta: report each shard's dispatch interval now that
        // everything is joined (workers can't reach the caller's
        // thread-local segment collector).
        for (s, (_, interval)) in replies.iter().enumerate() {
            if let Some((t0, t1)) = interval {
                crate::trace::push_seg("dispatch", Some(s as u32), *t0, *t1);
            }
        }
        replies
            .into_iter()
            .enumerate()
            .map(|(s, (reply, _))| {
                let r = match reply {
                    Reply::Out(r) => r,
                    // Shard 0 panic: re-raise on the replica thread
                    // (post-barrier), matching the days when shard 0
                    // ran on the caller — the supervision layer's
                    // poison/respawn path depends on it. A remote
                    // shard's panic fails only this dispatch, with
                    // shard attribution below.
                    Reply::Panicked(p) => {
                        if s == 0 {
                            resume_unwind(p);
                        }
                        Err(anyhow!("shard worker thread panicked"))
                    }
                };
                r.map_err(|e| anyhow!("shard {}: {:#}", s, e))
            })
            .collect()
    }
}

impl Backend for DeviceMesh {
    fn device_count(&self) -> usize {
        self.tp()
    }

    fn execute(&mut self, path: &Path, inputs: &[&xla::Literal]) -> Result<Vec<xla::Literal>> {
        DeviceMesh::execute(self, path, inputs)
    }

    fn execute_sharded(
        &mut self,
        dispatches: &[ShardDispatch<'_>],
    ) -> Result<Vec<Vec<xla::Literal>>> {
        DeviceMesh::execute_sharded(self, dispatches)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::literals::lit_f32;

    #[test]
    fn mesh_sizing_and_clamp() {
        let mesh = DeviceMesh::cpu(0).unwrap();
        assert_eq!(mesh.tp(), 1);
        let mesh = DeviceMesh::cpu(3).unwrap();
        assert_eq!(mesh.tp(), 3);
        assert_eq!(mesh.device_count(), 3);
        assert_eq!(mesh.stats(), (0, 0));
    }

    #[test]
    fn sharded_dispatch_arity_checked() {
        let mut mesh = DeviceMesh::cpu(2).unwrap();
        let x = lit_f32(&[1], &[0.0]).unwrap();
        let one = vec![ShardDispatch {
            path: PathBuf::from("/nonexistent/a.hlo.txt"),
            inputs: vec![&x],
        }];
        let err = mesh.execute_sharded(&one).unwrap_err();
        assert!(format!("{:#}", err).contains("arity"));
    }

    #[test]
    fn shard_errors_carry_shard_index() {
        // Both shards fail (missing artifacts); the error must name a
        // shard so mesh misconfiguration is debuggable.
        let mut mesh = DeviceMesh::cpu(2).unwrap();
        let x = lit_f32(&[1], &[0.0]).unwrap();
        let dispatches: Vec<ShardDispatch> = (0..2)
            .map(|s| ShardDispatch {
                path: PathBuf::from(format!("/nonexistent/shard{}.hlo.txt", s)),
                inputs: vec![&x],
            })
            .collect();
        let err = mesh.execute_sharded(&dispatches).unwrap_err();
        assert!(format!("{:#}", err).contains("shard 0"));
    }

    #[test]
    fn queued_execute_is_drained_on_drop() {
        // Dropping a Pending without waiting must still join the
        // in-flight job (the borrowed-input invariant) and leave the
        // worker usable.
        let mesh = DeviceMesh::cpu(1).unwrap();
        let x = lit_f32(&[1], &[0.0]).unwrap();
        {
            let _pending = mesh
                .execute_queued(Path::new("/nonexistent/q.hlo.txt"), &[&x])
                .unwrap();
            // dropped here without wait()
        }
        let err = mesh
            .execute_queued(Path::new("/nonexistent/q.hlo.txt"), &[&x])
            .unwrap()
            .wait()
            .unwrap_err();
        assert!(format!("{:#}", err).contains("q.hlo.txt"));
    }
}
