//! Device-mesh execution backend: D logical PJRT devices behind one
//! dispatch surface.
//!
//! A [`DeviceMesh`] owns one [`Runtime`] (client + executable cache) per
//! logical device. Single-device work (`tp_degree = 1`, replicated
//! artifacts like `calib_probe`, combine/`*_tail` stages) runs on device
//! 0 through [`DeviceMesh::execute`] — byte-for-byte the code path the
//! pre-mesh engine had. Head-sharded work fans one [`ShardDispatch`] per
//! device through [`DeviceMesh::execute_sharded`]: shard 0 executes on
//! the caller's thread, shards 1.. on scoped worker threads, and the
//! call joins all shards before returning (an all-or-nothing barrier —
//! the combine step needs every partial).
//!
//! Why scoped threads and not the shared [`crate::util::threadpool`]:
//! each device's `Runtime` is pinned to its shard for the executable
//! cache to stay warm per device, and a dispatch borrows the engine's
//! prebuilt weight literals — `std::thread::scope` supports both
//! (non-`'static` borrows, one worker per remote shard) where the job
//! pool's `'static` closures support neither. The cost is one OS thread
//! spawn+join per remote shard per dispatch (~tens of µs), which a
//! CPU-side XLA layer execution dwarfs; persistent per-device workers
//! would need `'static` (owned/unsafe) input hand-off and are the noted
//! follow-up if mesh dispatch overhead ever shows up in profiles. With
//! the vendored host-only `xla` stub, `Runtime` and `Literal` are plain
//! host data and cross the scope freely; a real PJRT backend keeps the
//! same shape with per-device contexts created on their worker threads.

use std::path::{Path, PathBuf};

use anyhow::{anyhow, bail, Context, Result};

use super::Runtime;

/// One shard's work item: the artifact to run on that device and its
/// borrowed input literals (activations + that shard's weight slices).
pub struct ShardDispatch<'a> {
    pub path: PathBuf,
    pub inputs: Vec<&'a xla::Literal>,
}

/// The execution surface the engine drives, named so an alternative
/// backend (a real multi-device PJRT client, a remote executor) has a
/// contract to implement. [`DeviceMesh`] is the only implementor today
/// and the engine holds it concretely — `execute`/`execute_sharded` are
/// inherent methods (the trait impl delegates), so callers need no
/// trait import.
pub trait Backend {
    /// Logical devices in the mesh (the tensor-parallel degree).
    fn device_count(&self) -> usize;

    /// Run a replicated artifact on device 0.
    fn execute(&mut self, path: &Path, inputs: &[&xla::Literal]) -> Result<Vec<xla::Literal>>;

    /// Run `dispatches[s]` on device `s` (one per device, in parallel)
    /// and return every shard's outputs in device order.
    fn execute_sharded(&mut self, dispatches: &[ShardDispatch<'_>])
        -> Result<Vec<Vec<xla::Literal>>>;
}

/// D logical devices, each with its own PJRT client + executable cache.
pub struct DeviceMesh {
    devices: Vec<Runtime>,
}

impl DeviceMesh {
    /// A mesh of `tp` CPU devices (`tp = 0` is clamped to 1).
    pub fn cpu(tp: usize) -> Result<DeviceMesh> {
        let devices = (0..tp.max(1))
            .map(|i| Runtime::cpu().with_context(|| format!("mesh device {}", i)))
            .collect::<Result<Vec<_>>>()?;
        Ok(DeviceMesh { devices })
    }

    /// Tensor-parallel degree (number of devices).
    pub fn tp(&self) -> usize {
        self.devices.len()
    }

    pub fn platform(&self) -> String {
        self.devices[0].platform()
    }

    /// Pre-compile an artifact on device 0 (warmup of replicated and
    /// combine-stage entries).
    pub fn load(&mut self, path: &Path) -> Result<()> {
        self.devices[0].load(path)
    }

    /// Pre-compile a per-shard artifact on its device (warmup).
    pub fn load_on(&mut self, device: usize, path: &Path) -> Result<()> {
        self.devices[device].load(path)
    }

    /// (compiled executables, total executions) summed over devices.
    pub fn stats(&self) -> (usize, u64) {
        self.devices
            .iter()
            .fold((0, 0), |(c, e), rt| (c + rt.cached(), e + rt.exec_count))
    }

    /// Run a replicated artifact on device 0.
    pub fn execute(
        &mut self,
        path: &Path,
        inputs: &[&xla::Literal],
    ) -> Result<Vec<xla::Literal>> {
        self.devices[0].execute(path, inputs)
    }

    /// Run `dispatches[s]` on device `s` (one per device, in parallel)
    /// and return every shard's outputs in device order.
    pub fn execute_sharded(
        &mut self,
        dispatches: &[ShardDispatch<'_>],
    ) -> Result<Vec<Vec<xla::Literal>>> {
        if dispatches.len() != self.devices.len() {
            bail!(
                "sharded dispatch arity {} != mesh devices {}",
                dispatches.len(),
                self.devices.len()
            );
        }
        if dispatches.len() == 1 {
            let d = &dispatches[0];
            let t0 = crate::trace::seg_begin();
            let out = self.devices[0].execute(&d.path, &d.inputs);
            crate::trace::seg_end("dispatch", Some(0), t0);
            return Ok(vec![out?]);
        }
        // Shard 0 on the caller's thread, shards 1.. on scoped workers;
        // join everything before combining (all-or-nothing). Traced
        // quanta (a segment collector is active on the replica thread)
        // time each shard on the trace clock — workers can't see the
        // caller's thread-local, so they carry a clone of the clock and
        // return their interval for the caller to report after the
        // join. Untraced dispatches have `clock = None` and skip every
        // timestamp.
        let clock = crate::trace::seg_clock();
        let (first, rest) = self.devices.split_at_mut(1);
        let (d0, drest) = dispatches.split_at(1);
        type ShardOut = (Result<Vec<xla::Literal>>, Option<(u64, u64)>);
        let results: Vec<ShardOut> = std::thread::scope(|scope| {
            let handles: Vec<_> = rest
                .iter_mut()
                .zip(drest)
                .map(|(rt, d)| {
                    let clock = clock.clone();
                    scope.spawn(move || {
                        let t0 = clock.as_ref().map(|c| c.now_ns());
                        let r = rt.execute(&d.path, &d.inputs);
                        let t1 = clock.as_ref().map(|c| c.now_ns());
                        (r, t0.zip(t1))
                    })
                })
                .collect();
            let t0 = clock.as_ref().map(|c| c.now_ns());
            let r0 = first[0].execute(&d0[0].path, &d0[0].inputs);
            let t1 = clock.as_ref().map(|c| c.now_ns());
            let mut out: Vec<ShardOut> = vec![(r0, t0.zip(t1))];
            for h in handles {
                // A panicking worker must fail this dispatch (with shard
                // attribution below), not take down the replica thread
                // that owns the whole device group.
                out.push(h.join().unwrap_or_else(|_| {
                    (Err(anyhow!("shard worker thread panicked")), None)
                }));
            }
            out
        });
        results
            .into_iter()
            .enumerate()
            .map(|(s, (r, interval))| {
                if let Some((t0, t1)) = interval {
                    crate::trace::push_seg("dispatch", Some(s as u32), t0, t1);
                }
                r.map_err(|e| anyhow!("shard {}: {:#}", s, e))
            })
            .collect()
    }
}

impl Backend for DeviceMesh {
    fn device_count(&self) -> usize {
        self.tp()
    }

    fn execute(&mut self, path: &Path, inputs: &[&xla::Literal]) -> Result<Vec<xla::Literal>> {
        DeviceMesh::execute(self, path, inputs)
    }

    fn execute_sharded(
        &mut self,
        dispatches: &[ShardDispatch<'_>],
    ) -> Result<Vec<Vec<xla::Literal>>> {
        DeviceMesh::execute_sharded(self, dispatches)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::literals::lit_f32;

    #[test]
    fn mesh_sizing_and_clamp() {
        let mesh = DeviceMesh::cpu(0).unwrap();
        assert_eq!(mesh.tp(), 1);
        let mesh = DeviceMesh::cpu(3).unwrap();
        assert_eq!(mesh.tp(), 3);
        assert_eq!(mesh.device_count(), 3);
        assert_eq!(mesh.stats(), (0, 0));
    }

    #[test]
    fn sharded_dispatch_arity_checked() {
        let mut mesh = DeviceMesh::cpu(2).unwrap();
        let x = lit_f32(&[1], &[0.0]).unwrap();
        let one = vec![ShardDispatch {
            path: PathBuf::from("/nonexistent/a.hlo.txt"),
            inputs: vec![&x],
        }];
        let err = mesh.execute_sharded(&one).unwrap_err();
        assert!(format!("{:#}", err).contains("arity"));
    }

    #[test]
    fn shard_errors_carry_shard_index() {
        // Both shards fail (missing artifacts); the error must name a
        // shard so mesh misconfiguration is debuggable.
        let mut mesh = DeviceMesh::cpu(2).unwrap();
        let x = lit_f32(&[1], &[0.0]).unwrap();
        let dispatches: Vec<ShardDispatch> = (0..2)
            .map(|s| ShardDispatch {
                path: PathBuf::from(format!("/nonexistent/shard{}.hlo.txt", s)),
                inputs: vec![&x],
            })
            .collect();
        let err = mesh.execute_sharded(&dispatches).unwrap_err();
        assert!(format!("{:#}", err).contains("shard 0"));
    }
}
