//! Persistent per-device worker threads: the command-queue execution
//! model behind [`DeviceMesh`](super::DeviceMesh).
//!
//! Each mesh device owns one long-lived OS thread (`fastav-dev{n}`)
//! that constructs its [`Runtime`] on-thread and then drains a FIFO
//! command queue. PJRT handles are not `Send` in this crate, so the
//! `Runtime` never leaves its worker; callers ship closures *to* it
//! and get results back over per-job completion channels. Compared to
//! the old scoped-thread fan-out this removes a thread spawn + join
//! per dispatch and — because submission returns a receiver instead of
//! blocking — lets the engine overlap host-side work (KV gather,
//! literal build) with an in-flight dispatch.
//!
//! Panic contract: a panicking job never takes the worker (or its
//! compiled-executable cache) down. The panic payload is caught and
//! shipped back through the job's completion channel as
//! [`JobOutcome::Panicked`], so the caller can re-raise it on its own
//! thread ([`DeviceWorker::call`] does exactly that) — preserving the
//! caller-thread panic semantics the replica supervision layer (PR 7)
//! depends on for poisoning and respawn.

use std::any::Any;
use std::panic::{catch_unwind, resume_unwind, AssertUnwindSafe};
use std::sync::mpsc;
use std::thread::JoinHandle;

use anyhow::{anyhow, bail, Result};

use super::Runtime;

/// A unit of work shipped to the worker: runs with exclusive access to
/// the device's `Runtime`. Jobs are responsible for reporting their own
/// result/panic over a channel (see [`DeviceWorker::submit_outcome`]).
type Job = Box<dyn FnOnce(&mut Runtime) + Send>;

enum Command {
    Run(Job),
    Shutdown,
}

/// How a submitted job finished on the worker thread.
pub enum JobOutcome<T> {
    Done(T),
    /// The job panicked; this is the payload `catch_unwind` captured.
    /// Re-raise with `std::panic::resume_unwind` for caller-thread
    /// parity, or map to an error for shard-attributed reporting.
    Panicked(Box<dyn Any + Send>),
}

/// One persistent device worker: a named thread owning a `Runtime`,
/// fed through a FIFO command queue. Dropping the worker enqueues a
/// shutdown command (queued jobs drain first) and joins the thread.
pub struct DeviceWorker {
    device: usize,
    tx: mpsc::Sender<Command>,
    handle: Option<JoinHandle<()>>,
}

impl DeviceWorker {
    /// Spawn the worker thread and construct its `Runtime` on-thread.
    /// Blocks until the runtime is up (or failed), so a mesh that
    /// built successfully is ready to execute.
    pub fn spawn(device: usize) -> Result<DeviceWorker> {
        let (tx, rx) = mpsc::channel::<Command>();
        let (ready_tx, ready_rx) = mpsc::channel::<Result<()>>();
        let handle = std::thread::Builder::new()
            .name(format!("fastav-dev{}", device))
            .spawn(move || worker_main(rx, ready_tx))
            .map_err(|e| anyhow!("spawning device {} worker: {}", device, e))?;
        match ready_rx.recv() {
            Ok(Ok(())) => Ok(DeviceWorker { device, tx, handle: Some(handle) }),
            Ok(Err(e)) => {
                let _ = handle.join();
                Err(e)
            }
            Err(_) => {
                let _ = handle.join();
                bail!("device {} worker exited during startup", device)
            }
        }
    }

    /// Logical device index this worker serves.
    pub fn device(&self) -> usize {
        self.device
    }

    /// Enqueue `f` and return a receiver for its outcome without
    /// blocking. Jobs run in submission (FIFO) order; a panic inside
    /// `f` arrives as [`JobOutcome::Panicked`] and leaves the worker
    /// alive for subsequent jobs.
    pub fn submit_outcome<T, F>(&self, f: F) -> Result<mpsc::Receiver<JobOutcome<T>>>
    where
        T: Send + 'static,
        F: FnOnce(&mut Runtime) -> T + Send + 'static,
    {
        let (out_tx, out_rx) = mpsc::channel();
        let job: Job = Box::new(move |rt| {
            let res = catch_unwind(AssertUnwindSafe(|| f(rt)));
            let _ = out_tx.send(match res {
                Ok(v) => JobOutcome::Done(v),
                Err(p) => JobOutcome::Panicked(p),
            });
        });
        self.tx
            .send(Command::Run(job))
            .map_err(|_| anyhow!("device {} worker is gone", self.device))?;
        Ok(out_rx)
    }

    /// Run `f` on the worker and wait for it. A panic inside `f` is
    /// re-raised on this thread — exactly as if `f` had run here —
    /// which is what keeps shard-0 panic semantics identical to the
    /// pre-worker (caller-thread) execution path.
    pub fn call<T, F>(&self, f: F) -> Result<T>
    where
        T: Send + 'static,
        F: FnOnce(&mut Runtime) -> T + Send + 'static,
    {
        let rx = self.submit_outcome(f)?;
        match rx.recv() {
            Ok(JobOutcome::Done(v)) => Ok(v),
            Ok(JobOutcome::Panicked(p)) => resume_unwind(p),
            Err(_) => bail!("device {} worker died before completing the job", self.device),
        }
    }
}

impl Drop for DeviceWorker {
    fn drop(&mut self) {
        // FIFO queue: already-submitted jobs drain before Shutdown is
        // seen, so in-flight receivers still get their outcomes.
        let _ = self.tx.send(Command::Shutdown);
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

fn worker_main(rx: mpsc::Receiver<Command>, ready_tx: mpsc::Sender<Result<()>>) {
    let mut rt = match Runtime::cpu() {
        Ok(rt) => {
            let _ = ready_tx.send(Ok(()));
            rt
        }
        Err(e) => {
            let _ = ready_tx.send(Err(e));
            return;
        }
    };
    while let Ok(cmd) = rx.recv() {
        match cmd {
            Command::Run(job) => {
                // Backstop only: `submit_outcome` jobs already catch
                // their own panics. This keeps the worker (and its
                // executable cache) alive even if a future job type
                // forgets to.
                let _ = catch_unwind(AssertUnwindSafe(|| job(&mut rt)));
            }
            Command::Shutdown => break,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::trace::{collect_segs, push_seg, seg_begin, seg_end_overlap, Clock, MockClock};
    use std::sync::{Arc, Mutex};

    #[test]
    fn jobs_run_in_submission_order() {
        let w = DeviceWorker::spawn(0).unwrap();
        let order = Arc::new(Mutex::new(Vec::new()));
        let rxs: Vec<_> = (0..8usize)
            .map(|i| {
                let order = Arc::clone(&order);
                w.submit_outcome(move |_rt| {
                    order.lock().unwrap().push(i);
                    i
                })
                .unwrap()
            })
            .collect();
        for (i, rx) in rxs.into_iter().enumerate() {
            match rx.recv().unwrap() {
                JobOutcome::Done(v) => assert_eq!(v, i),
                JobOutcome::Panicked(_) => panic!("job {} panicked", i),
            }
        }
        assert_eq!(*order.lock().unwrap(), (0..8).collect::<Vec<_>>());
    }

    #[test]
    fn drop_drains_queued_jobs_then_shuts_down() {
        let ran = Arc::new(Mutex::new(Vec::new()));
        {
            let w = DeviceWorker::spawn(0).unwrap();
            for i in 0..4usize {
                let ran = Arc::clone(&ran);
                let _rx = w
                    .submit_outcome(move |_rt| ran.lock().unwrap().push(i))
                    .unwrap();
            }
            // Drop joins the worker; queued jobs must complete first.
        }
        assert_eq!(*ran.lock().unwrap(), (0..4).collect::<Vec<_>>());
    }

    #[test]
    fn panicking_job_fails_only_itself_and_worker_survives() {
        let w = DeviceWorker::spawn(0).unwrap();
        let rx = w
            .submit_outcome(|_rt| -> usize { panic!("boom-7") })
            .unwrap();
        match rx.recv().unwrap() {
            JobOutcome::Panicked(p) => {
                let msg = p.downcast_ref::<&str>().copied().unwrap_or("");
                assert_eq!(msg, "boom-7", "panic payload must cross the channel intact");
            }
            JobOutcome::Done(_) => panic!("expected a panic outcome"),
        }
        // The worker (and its Runtime) survived the panic.
        assert_eq!(w.call(|_rt| 41 + 1).unwrap(), 42);
    }

    #[test]
    fn call_reraises_panics_on_the_caller_thread() {
        let w = DeviceWorker::spawn(0).unwrap();
        let res = catch_unwind(AssertUnwindSafe(|| w.call(|_rt| -> usize { panic!("caller sees this") })));
        let p = res.expect_err("call must resume_unwind the job panic");
        assert_eq!(p.downcast_ref::<&str>().copied().unwrap_or(""), "caller sees this");
        // Still usable afterwards.
        assert_eq!(w.call(|_rt| 7usize).unwrap(), 7);
    }

    /// Deterministic pipelining proof on a MockClock: the caller's
    /// "upload" segment (gather + literal build for the next layer) is
    /// timed while the worker's "dispatch" job is still in flight, and
    /// the resulting trace segments overlap. A two-way handshake
    /// sequences the clock advances so the timeline is exact:
    ///
    ///   t=0   worker stamps dispatch start, acks
    ///   t=10  caller begins upload       (dispatch in flight)
    ///   t=20  caller ends upload (overlap=true), releases worker
    ///   t=20  worker stamps dispatch end
    #[test]
    fn upload_overlaps_inflight_dispatch_on_mock_clock() {
        let mock = Arc::new(MockClock::new());
        let clock: Arc<dyn Clock> = mock.clone();
        let w = DeviceWorker::spawn(0).unwrap();

        let (start_tx, start_rx) = mpsc::channel::<()>();
        let (ack_tx, ack_rx) = mpsc::channel::<()>();
        let (end_tx, end_rx) = mpsc::channel::<()>();

        let ((), segs) = collect_segs(&clock, || {
            let wclock = crate::trace::seg_clock().expect("collector installed");
            let rx = w
                .submit_outcome(move |_rt| {
                    start_rx.recv().unwrap();
                    let t0 = wclock.now_ns();
                    ack_tx.send(()).unwrap();
                    end_rx.recv().unwrap();
                    let t1 = wclock.now_ns();
                    (t0, t1)
                })
                .unwrap();
            start_tx.send(()).unwrap();
            ack_rx.recv().unwrap(); // dispatch start stamped at t=0
            mock.advance_ns(10);
            let up = seg_begin(); // upload starts at t=10
            mock.advance_ns(10);
            seg_end_overlap("upload", None, up, true); // ends at t=20
            end_tx.send(()).unwrap();
            let (t0, t1) = match rx.recv().unwrap() {
                JobOutcome::Done(v) => v,
                JobOutcome::Panicked(_) => panic!("dispatch job panicked"),
            };
            push_seg("dispatch", Some(0), t0, t1);
        });

        let up = segs.iter().find(|s| s.name == "upload").expect("upload seg");
        let disp = segs.iter().find(|s| s.name == "dispatch").expect("dispatch seg");
        assert!(up.overlap, "upload must be marked as overlapping");
        assert!(!disp.overlap);
        assert_eq!((disp.start_ns, disp.end_ns), (0, 20));
        assert_eq!((up.start_ns, up.end_ns), (10, 20));
        assert!(
            up.start_ns >= disp.start_ns && up.end_ns <= disp.end_ns,
            "upload [{}, {}] must lie within the in-flight dispatch [{}, {}]",
            up.start_ns,
            up.end_ns,
            disp.start_ns,
            disp.end_ns
        );
    }
}
