//! FastAV CLI: serve / eval / calibrate / info.
//!
//! ```text
//! fastav serve     --model vl2sim --port 8077 [--no-pruning] [--p 20]
//!                  [--replicas 4] [--max-inflight 4] [--kv-budget-mb 512]
//!                  [--prefix-cache-mb 256] [--decode-batch 0] [--tp 1]
//!                  [--policies policies.json] [--profile balanced]
//!                  [--pipeline on|off]
//!                  [--tier-ram-mb 0] [--tier-disk-path kv.tier]
//!                  [--tier-disk-mb 0] [--tier-prune-budget 32]
//!                  [--grpc-port 0] [--stream-channel 32]
//! fastav eval      --model vl2sim --dataset avhbench --n 50 [--no-pruning]
//! fastav calibrate --model vl2sim --n 100
//! fastav info      --model vl2sim
//! ```
//!
//! `serve` exposes the profile registry: the four built-ins (`quality`/
//! `balanced`/`aggressive`/`off`) derived from the calibration, extended
//! or overridden by the `--policies <json>` file (schema in ROADMAP.md;
//! example in `examples/policies.example.json`), with `--profile`
//! picking the default profile `/v1/generate` serves.

use std::sync::Arc;

use anyhow::{anyhow, Result};

use fastav::avsynth::Dataset;
use fastav::calibration::{calibrate, Calibration};
use fastav::coordinator::Coordinator;
use fastav::eval::evaluate;
use fastav::http::{Handler, Server};
use fastav::model::{ModelEngine, PruningPlan};
use fastav::policy::PolicyRegistry;
use fastav::util::cli::Args;

const OPTIONS: &[&str] = &[
    "model", "artifacts", "dataset", "n", "port", "p", "no-pruning", "seed",
    "max-gen", "queue-cap", "workers", "calibration", "replicas",
    "max-inflight", "kv-budget-mb", "deadline-ms", "prefix-cache-mb",
    "decode-batch", "tp", "policies", "profile", "trace-sample", "trace-ring",
    "pipeline", "tier-ram-mb", "tier-disk-path", "tier-disk-mb",
    "tier-prune-budget", "grpc-port", "stream-channel",
];

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let parsed = match Args::parse(args, OPTIONS) {
        Ok(a) => a,
        Err(e) => {
            eprintln!("error: {}", e);
            eprintln!("usage: fastav <serve|eval|calibrate|info> [--model vl2sim] ...");
            std::process::exit(2);
        }
    };
    let result = match parsed.subcommand.as_deref() {
        Some("serve") => cmd_serve(&parsed),
        Some("eval") => cmd_eval(&parsed),
        Some("calibrate") => cmd_calibrate(&parsed),
        Some("info") => cmd_info(&parsed),
        other => {
            eprintln!("unknown subcommand {:?}", other);
            eprintln!("usage: fastav <serve|eval|calibrate|info> [--model vl2sim] ...");
            std::process::exit(2);
        }
    };
    if let Err(e) = result {
        eprintln!("error: {:#}", e);
        std::process::exit(1);
    }
}

fn artifact_root(args: &Args) -> std::path::PathBuf {
    std::path::PathBuf::from(args.get_or("artifacts", "artifacts"))
}

fn load_calibration(args: &Args, root: &std::path::Path, model: &str) -> Result<Calibration> {
    let path = match args.get("calibration") {
        Some(p) => std::path::PathBuf::from(p),
        None => root.join(model).join("calibration.json"),
    };
    Calibration::load(&path).map_err(|e| {
        anyhow!("{:#}. Run `fastav calibrate --model {}` first.", e, model)
    })
}

fn plan_from_args(args: &Args, root: &std::path::Path, model: &str) -> Result<PruningPlan> {
    if args.has_flag("no-pruning") {
        return Ok(PruningPlan::vanilla());
    }
    let p = args.get_f64("p", 20.0).map_err(|e| anyhow!(e))?;
    let calib = load_calibration(args, root, model)?;
    Ok(calib.plan(p))
}

/// Build the serving profile registry: the calibrated built-ins (or the
/// `off`-only registry under `--no-pruning`), extended by `--policies`,
/// with `--profile` selecting the default.
fn registry_from_args(
    args: &Args,
    root: &std::path::Path,
    model: &str,
) -> Result<PolicyRegistry> {
    let mut registry = if args.has_flag("no-pruning") {
        PolicyRegistry::off_only()
    } else {
        let p = args.get_f64("p", 20.0).map_err(|e| anyhow!(e))?;
        let calib = load_calibration(args, root, model)?;
        PolicyRegistry::builtin(&calib, p)
    };
    if let Some(path) = args.get("policies") {
        let text = std::fs::read_to_string(path)
            .map_err(|e| anyhow!("reading --policies {}: {}", path, e))?;
        let added = registry
            .merge_policies_json(&text)
            .map_err(|e| anyhow!("--policies {}: {}", path, e))?;
        println!("loaded {} operator profile(s) from {}", added, path);
    }
    if let Some(name) = args.get("profile") {
        registry.set_default(name).map_err(|e| anyhow!(e))?;
    }
    Ok(registry)
}

fn cmd_calibrate(args: &Args) -> Result<()> {
    let root = artifact_root(args);
    let model = args.get_or("model", "vl2sim").to_string();
    let n = args.get_usize("n", 100).map_err(|e| anyhow!(e))?;
    let seed = args.get_usize("seed", 1234).map_err(|e| anyhow!(e))? as u64;
    let mut engine = ModelEngine::load(&root, &model)?;
    println!("calibrating {} over {} samples...", model, n);
    let calib = calibrate(&mut engine, n, seed)?;
    println!(
        "  threshold {:.5}  vis_cutoff {}  keep_audio {}  keep_frames {}  budget {}",
        calib.threshold, calib.vis_cutoff, calib.keep_audio, calib.keep_frames, calib.budget
    );
    let out = root.join(&model).join("calibration.json");
    calib.save(&out)?;
    println!("wrote {:?}", out);
    Ok(())
}

fn cmd_eval(args: &Args) -> Result<()> {
    let root = artifact_root(args);
    let model = args.get_or("model", "vl2sim").to_string();
    let dataset = Dataset::parse(args.get_or("dataset", "avhbench"))
        .ok_or_else(|| anyhow!("unknown dataset"))?;
    let n = args.get_usize("n", 50).map_err(|e| anyhow!(e))?;
    let seed = args.get_usize("seed", 1234).map_err(|e| anyhow!(e))? as u64;
    let max_gen = args.get_usize("max-gen", 4).map_err(|e| anyhow!(e))?;
    let plan = plan_from_args(args, &root, &model)?;
    let mut engine = ModelEngine::load(&root, &model)?;
    engine.warmup()?;
    let report = evaluate(&mut engine, dataset, n, seed, &plan, max_gen)?;
    println!(
        "model={} dataset={} n={} pruning={}",
        model,
        report.dataset,
        report.n,
        if args.has_flag("no-pruning") { "off" } else { "fastav" }
    );
    println!(
        "  accuracy {:.1}%  rel_flops {:.1}  prefill {:.1}ms  per-token {:.1}ms  kv {:.1}MB",
        report.accuracy(),
        report.mean_rel_flops,
        report.mean_prefill_s * 1e3,
        report.mean_decode_tok_s * 1e3,
        report.mean_peak_kv_bytes / 1e6,
    );
    for (name, s) in &report.per_subtask {
        if name == "captioning" {
            println!("    {:<18} n={:<4} score {:.2}/5", name, s.n, s.caption_mean());
        } else {
            println!("    {:<18} n={:<4} acc {:.1}%", name, s.n, s.accuracy());
        }
    }
    Ok(())
}

fn cmd_info(args: &Args) -> Result<()> {
    let root = artifact_root(args);
    let model = args.get_or("model", "vl2sim").to_string();
    let engine = ModelEngine::load(&root, &model)?;
    let cfg = &engine.cfg;
    println!("model {}", cfg.name);
    println!(
        "  d_model {}  heads {}x{}  layers {} (mid {})  ff {}  vocab {}",
        cfg.d_model, cfg.n_heads, cfg.d_head, cfg.n_layers, cfg.mid_layer, cfg.d_ff, cfg.vocab
    );
    println!(
        "  layout: frames {} x {} vis/frame, {} audio tokens, interleaved={}",
        cfg.layout.frames,
        cfg.layout.vis_per_frame,
        cfg.layout.audio_tokens(),
        cfg.layout.interleaved
    );
    println!("  kernel impl: {}", cfg.kernel_impl);
    for entry in ["prefill_front", "back_layer", "decode_layer", "calib_probe"] {
        println!("  {} buckets: {:?}", entry, engine.artifacts().buckets(entry));
    }
    Ok(())
}

fn cmd_serve(args: &Args) -> Result<()> {
    let root = artifact_root(args);
    let model = args.get_or("model", "vl2sim").to_string();
    let port = args.get_usize("port", 8077).map_err(|e| anyhow!(e))?;
    let queue_cap = args.get_usize("queue-cap", 64).map_err(|e| anyhow!(e))?;
    let workers = args.get_usize("workers", 4).map_err(|e| anyhow!(e))?;
    let max_gen = args.get_usize("max-gen", 4).map_err(|e| anyhow!(e))?;
    let replicas = args.get_usize("replicas", 1).map_err(|e| anyhow!(e))?;
    let max_inflight = args.get_usize("max-inflight", 4).map_err(|e| anyhow!(e))?;
    let kv_budget_mb = args.get_usize("kv-budget-mb", 0).map_err(|e| anyhow!(e))?;
    let prefix_cache_mb = args.get_usize("prefix-cache-mb", 0).map_err(|e| anyhow!(e))?;
    let deadline_ms = args.get_usize("deadline-ms", 0).map_err(|e| anyhow!(e))?;
    // 0 = fuse up to the artifact set's largest batch bucket; 1 = force
    // the single-token decode path (A/B comparison).
    let decode_batch = args.get_usize("decode-batch", 0).map_err(|e| anyhow!(e))?;
    // Tensor-parallel degree: each replica becomes a device group of
    // this many mesh devices (needs artifacts lowered with tp_degree).
    let tp = args.get_usize("tp", 1).map_err(|e| anyhow!(e))?;
    // Request-lifecycle tracing: sample rate in [0, 1] (0 = off, the
    // default — the untraced path takes one branch and allocates
    // nothing) and per-replica completed-trace ring capacity.
    let trace_sample = args.get_f64("trace-sample", 0.0).map_err(|e| anyhow!(e))?;
    let trace_ring = args.get_usize("trace-ring", 256).map_err(|e| anyhow!(e))?;
    // Pipelined quantum execution (overlap next layer's KV upload with
    // the in-flight dispatch). On by default; `--pipeline off` forces
    // the strict sequential ordering for A/B comparison.
    let pipeline = match args.get_or("pipeline", "on") {
        "on" => true,
        "off" => false,
        other => return Err(anyhow!("--pipeline must be on|off, got {:?}", other)),
    };
    // Spill tier below the device prefix cache: evictions demote into
    // host RAM (`--tier-ram-mb`) and then disk (`--tier-disk-path` +
    // `--tier-disk-mb`) instead of dropping; a background pruner does
    // the serialization/compaction in `--tier-prune-budget`-entry runs.
    // Both sizes default to 0 = tier disabled (pre-tier behavior).
    let tier_ram_mb = args.get_usize("tier-ram-mb", 0).map_err(|e| anyhow!(e))?;
    let tier_disk_mb = args.get_usize("tier-disk-mb", 0).map_err(|e| anyhow!(e))?;
    let tier_disk_path = args.get("tier-disk-path").map(std::path::PathBuf::from);
    let tier_prune_budget =
        args.get_usize("tier-prune-budget", 32).map_err(|e| anyhow!(e))?;
    // Streamed delivery: per-request token-channel capacity (the park
    // threshold — a consumer this many tokens behind is gated out of
    // decode quanta until it drains) and the optional gRPC front door
    // (0 = HTTP only).
    let stream_channel = args.get_usize("stream-channel", 32).map_err(|e| anyhow!(e))?;
    let grpc_port = args.get_usize("grpc-port", 0).map_err(|e| anyhow!(e))?;
    if tier_disk_mb > 0 && tier_disk_path.is_none() {
        return Err(anyhow!("--tier-disk-mb requires --tier-disk-path"));
    }
    let tier_disk_path_display = tier_disk_path
        .as_deref()
        .map(|p| p.display().to_string())
        .unwrap_or_else(|| "none".to_string());
    let registry = Arc::new(registry_from_args(args, &root, &model)?);

    // Replica pool: each engine lives on its own thread.
    let cfg = fastav::serving::PoolConfig {
        replicas,
        queue_cap,
        max_inflight,
        kv_budget_bytes: kv_budget_mb * (1 << 20),
        prefix_cache_bytes: prefix_cache_mb * (1 << 20),
        warmup: true,
        default_deadline: if deadline_ms == 0 {
            None
        } else {
            Some(std::time::Duration::from_millis(deadline_ms as u64))
        },
        max_decode_batch: decode_batch,
        tp_degree: tp,
        trace_sample,
        trace_ring,
        pipeline,
        tier_ram_bytes: tier_ram_mb * (1 << 20),
        tier_disk_path,
        tier_disk_bytes: tier_disk_mb * (1 << 20),
        tier_prune_entries: tier_prune_budget,
        stream_channel_cap: stream_channel,
        ..Default::default()
    };
    let coord = Arc::new(Coordinator::start_pool(root.clone(), model.clone(), cfg)?);
    let layout = {
        // Load config cheaply for request assembly.
        let cfg = fastav::model::ModelConfig::load(&root.join(&model).join("model.json"))?;
        cfg.layout
    };

    let handler: Handler = fastav::http::api::make_handler(
        Arc::clone(&coord),
        layout.clone(),
        Arc::clone(&registry),
        max_gen,
        1234,
    );
    let server = Server::bind(&format!("127.0.0.1:{}", port), workers, handler)?;

    // Optional gRPC front door: same assembly/submission path as HTTP
    // (unary Generate + server-streaming GenerateStream), on its own
    // accept thread so the HTTP serve loop below stays unchanged.
    let grpc_shutdown = if grpc_port > 0 {
        let grpc = fastav::streaming::grpc::GrpcServer::bind(
            &format!("127.0.0.1:{}", grpc_port),
            workers,
            fastav::streaming::grpc::GrpcCtx {
                coord: Arc::clone(&coord),
                layout: layout.clone(),
                registry: Arc::clone(&registry),
                max_gen,
                base_seed: 1234,
            },
        )?;
        let addr = grpc.local_addr();
        let handle = grpc.shutdown_handle();
        std::thread::Builder::new()
            .name("grpc-accept".into())
            .spawn(move || grpc.serve())
            .map_err(|e| anyhow!("spawning gRPC accept thread: {}", e))?;
        println!("fastav gRPC on http2://{} (fastav.v1.FastAV)", addr);
        Some(handle)
    } else {
        None
    };
    println!(
        "fastav serving {} on http://{} ({} replica(s) × tp={})",
        model,
        server.local_addr(),
        coord.replica_count(),
        tp.max(1)
    );
    println!(
        "  profiles: [{}]  default: {}",
        registry.names().join(", "),
        registry.default_name()
    );
    println!("  POST /v2/generate     {{\"profile\": \"aggressive\", \"pruning\": {{...}}?, \"dataset\": \"avhbench\", \"index\": 0, \"stream\": true?}}");
    println!("  POST /v1/generate     {{\"dataset\": \"avhbench\", \"index\": 0, \"question\": \"what_scene\"?}}");
    println!("  GET  /v1/policies     (profile registry + spec hashes)");
    println!("  POST /v1/cancel       {{\"request_id\": 1}}");
    println!("  POST /v1/cache/flush  (drain device + RAM + disk cache tiers)");
    if tier_ram_mb > 0 || tier_disk_path_display != "none" {
        println!(
            "  KV spill tier: ram {} MiB, disk {} MiB ({}), prune budget {} entries/run",
            tier_ram_mb,
            tier_disk_mb,
            tier_disk_path_display,
            tier_prune_budget.max(1)
        );
    }
    if trace_sample > 0.0 {
        println!(
            "  GET  /v1/traces       GET /v1/trace/{{id}}[?format=chrome]  (sampling 1/{} requests)",
            (1.0 / trace_sample.min(1.0)).round().max(1.0) as u64
        );
    }
    println!("  GET  /v1/pool         GET /v1/health    GET /metrics      GET /healthz");
    let shutdown = server.shutdown_handle();
    ctrlc_fallback(&shutdown);
    server.serve();
    if let Some(h) = grpc_shutdown {
        h.store(true, std::sync::atomic::Ordering::SeqCst);
    }
    Ok(())
}

/// Without a signal-handling crate, serve until stdin closes (Ctrl-D) or
/// the process is killed; the flag lets tests stop the loop.
fn ctrlc_fallback(_shutdown: &Arc<std::sync::atomic::AtomicBool>) {}
