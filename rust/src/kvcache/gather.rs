//! Stateful batched-decode upload buffers: remember what each `[H, cap,
//! dh]` batch row holds so the next quantum's gather can skip work.
//!
//! The stateless [`LayerCache::padded_kv_batch_into`] re-gathers every
//! live row and re-zeroes the full padding region on every call. During
//! steady-state decode that is almost all waste: a generation's block
//! list changes by exactly one appended row per step, and the batch
//! composition is stable for quanta at a time. A [`GatherBuf`] tracks,
//! per batch row, *which cache at which epoch and length* it gathered
//! last time:
//!
//! * same cache ([`LayerCache::id`]), same row-stability epoch
//!   ([`LayerCache::epoch`]), longer-or-equal length → **delta-append**:
//!   copy only the new tail rows ([`LayerCache::padded_kv_fill_tail`]),
//!   typically one row per head per step;
//! * anything else → full re-gather, but zeroing only the extent the
//!   previous occupant actually wrote
//!   ([`LayerCache::padded_kv_fill_ext`]) instead of the whole slice.
//!
//! Validity is airtight because the (`id`, `epoch`) tuple changes on
//! exactly the operations that could invalidate previously-gathered
//! rows: `compact` moves rows (epoch bump), `clone` can diverge through
//! copy-on-write (fresh id), while `append`/`grow`/COW tail forks
//! preserve the live prefix byte-for-byte (no change). The capacity and
//! head geometry are part of the buffer's own state: any restride marks
//! every row stale. Equivalence with the stateless gather is
//! property-tested below against random append/compact/grow/clone/
//! batch-shuffle sequences.

use super::LayerCache;

/// What one `[H, cap, dh]` batch row of the buffer currently holds.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum RowFill {
    /// Unknown contents (fresh slice, or the buffer was restrided):
    /// must be fully rewritten, zeroing the whole row band.
    Stale,
    /// All-zero padding row from the previous fill.
    Zero,
    /// Gathered from cache `id` at row-stability `epoch`, with rows
    /// `0..len` live (and `len..` zero).
    Cache { id: u64, epoch: u64, len: usize },
}

/// Per-fill accounting: how many batch rows took the cheap delta path
/// vs a full re-gather (surfaced by the mesh-overhead bench).
#[derive(Debug, Default, Clone, Copy, PartialEq, Eq)]
pub struct GatherStats {
    pub delta_rows: usize,
    pub full_rows: usize,
}

/// A persistent `[rows, H, cap, dh]` upload buffer pair with per-row
/// validity tracking. One per layer in the pipelined engine (the row
/// state is only reusable if the same layer's caches land in the same
/// buffer every quantum). High-water sized, never shrunk.
#[derive(Debug, Default)]
pub struct GatherBuf {
    k: Vec<f32>,
    v: Vec<f32>,
    cap: usize,
    n_heads: usize,
    d_head: usize,
    rows: Vec<RowFill>,
}

impl GatherBuf {
    pub fn new() -> GatherBuf {
        GatherBuf::default()
    }

    /// The gathered K slab; only the first `rows * H * cap * dh`
    /// elements of the most recent [`Self::fill`] are meaningful.
    pub fn k(&self) -> &[f32] {
        &self.k
    }

    pub fn v(&self) -> &[f32] {
        &self.v
    }

    /// Drop all validity state (the buffers stay allocated). The next
    /// fill re-gathers everything — used when the pipelined path is
    /// switched off/on at runtime so stale state can never leak across.
    pub fn invalidate(&mut self) {
        for r in self.rows.iter_mut() {
            *r = RowFill::Stale;
        }
    }

    /// Gather `caches[b]` into batch row `b` at joint capacity `cap`
    /// (rows `caches.len()..rows` are padding and read zero), exactly
    /// like [`LayerCache::padded_kv_batch_into`] — but reusing whatever
    /// this buffer already holds from the previous fill.
    pub fn fill(&mut self, caches: &[&LayerCache], rows: usize, cap: usize) -> GatherStats {
        let mut stats = GatherStats::default();
        assert!(caches.len() <= rows, "{} caches > {} batch rows", caches.len(), rows);
        let Some(first) = caches.first() else {
            assert_eq!(rows, 0, "empty batch cannot have padding rows");
            return stats;
        };
        let (h_n, dh) = (first.n_heads, first.d_head);
        if (self.cap, self.n_heads, self.d_head) != (cap, h_n, dh) {
            // Restride: every existing row's layout is wrong now.
            self.invalidate();
            self.cap = cap;
            self.n_heads = h_n;
            self.d_head = dh;
        }
        let per = h_n * cap * dh;
        let elems = per * rows;
        if self.k.len() < elems {
            self.k.resize(elems, 0.0);
            self.v.resize(elems, 0.0);
        }
        // New batch rows may land on bytes an earlier, larger config
        // wrote (high-water buffers): conservatively stale.
        if self.rows.len() < rows {
            self.rows.resize(rows, RowFill::Stale);
        }
        for b in 0..rows {
            let prev = self.rows[b];
            let ks = &mut self.k[b * per..(b + 1) * per];
            let vs = &mut self.v[b * per..(b + 1) * per];
            if let Some(c) = caches.get(b) {
                assert_eq!(
                    (c.n_heads, c.d_head),
                    (h_n, dh),
                    "batch caches must share one head geometry"
                );
                match prev {
                    RowFill::Cache { id, epoch, len }
                        if id == c.id() && epoch == c.epoch() && len <= c.len() =>
                    {
                        c.padded_kv_fill_tail(cap, len, ks, vs);
                        stats.delta_rows += 1;
                    }
                    _ => {
                        let prev_rows = match prev {
                            RowFill::Zero => 0,
                            RowFill::Stale => cap,
                            RowFill::Cache { len, .. } => len,
                        };
                        c.padded_kv_fill_ext(cap, ks, vs, prev_rows);
                        stats.full_rows += 1;
                    }
                }
                self.rows[b] = RowFill::Cache { id: c.id(), epoch: c.epoch(), len: c.len() };
            } else {
                // Padding row: zero only what the previous occupant wrote.
                match prev {
                    RowFill::Zero => {}
                    RowFill::Stale => {
                        ks.fill(0.0);
                        vs.fill(0.0);
                    }
                    RowFill::Cache { len, .. } => {
                        for h in 0..h_n {
                            let base = h * cap * dh;
                            ks[base..base + len * dh].fill(0.0);
                            vs[base..base + len * dh].fill(0.0);
                        }
                    }
                }
                self.rows[b] = RowFill::Zero;
            }
        }
        stats
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kvcache::{BlockPool, BLOCK_TOKENS};
    use crate::util::proptest::{run_prop, Gen};

    fn rand_row(g: &mut Gen, w: usize, tag: f32) -> Vec<f32> {
        (0..w).map(|_| tag + (g.f64_unit() as f32)).collect()
    }

    /// Reference oracle: the stateless batch gather into fresh buffers.
    fn oracle(caches: &[&LayerCache], rows: usize, cap: usize) -> (Vec<f32>, Vec<f32>) {
        let mut k = Vec::new();
        let mut v = Vec::new();
        LayerCache::padded_kv_batch_into(caches, rows, cap, &mut k, &mut v);
        (k, v)
    }

    #[test]
    fn delta_fill_matches_stateless_gather_under_random_mutation() {
        // The core delta-append validity property: across arbitrary
        // interleavings of append / compact / grow / clone-swap /
        // batch reshuffles / cap changes, a persistent GatherBuf must
        // produce byte-identical upload slabs to a fresh stateless
        // gather every single quantum.
        run_prop("gatherbuf_matches_stateless", 40, |g| {
            let pool = BlockPool::new();
            let (h_n, dh) = (g.usize_in(1, 3), g.usize_in(1, 4));
            let w = h_n * dh;
            let mut caps = vec![2 * BLOCK_TOKENS, 4 * BLOCK_TOKENS];
            let mut caches: Vec<LayerCache> = (0..g.usize_in(2, 4))
                .map(|i| {
                    let mut c = LayerCache::new_in(pool.clone(), h_n, dh, caps[0]);
                    for r in 0..g.usize_in(1, BLOCK_TOKENS + 4) {
                        let k = rand_row(g, w, (i * 100 + r) as f32);
                        let v = rand_row(g, w, -((i * 100 + r) as f32));
                        c.append(&k, &v, r as i32);
                    }
                    c
                })
                .collect();
            let mut buf = GatherBuf::new();
            for _step in 0..12 {
                // Mutate a random cache with a random operation.
                let ci = g.usize_in(0, caches.len() - 1);
                match g.usize_in(0, 4) {
                    0 => {
                        let c = &mut caches[ci];
                        if c.len() < c.cap() {
                            let pos = c.len() as i32;
                            let k = rand_row(g, w, 7_000.0 + pos as f32);
                            let v = rand_row(g, w, -7_000.0 - pos as f32);
                            c.append(&k, &v, pos);
                        }
                    }
                    1 => {
                        let c = &mut caches[ci];
                        if c.len() > 1 {
                            let keep: Vec<usize> =
                                (0..c.len()).filter(|_| g.f64_unit() < 0.7).collect();
                            if !keep.is_empty() {
                                c.compact(&keep);
                            }
                        }
                    }
                    2 => {
                        let c = &mut caches[ci];
                        let cur = c.cap();
                        c.grow(cur + BLOCK_TOKENS);
                        caps.push(cur + BLOCK_TOKENS);
                    }
                    3 => {
                        // Replace with a clone that then diverges: the
                        // fresh id must force a full re-gather.
                        let mut c = caches[ci].clone();
                        if c.len() > 1 {
                            let keep: Vec<usize> = (0..c.len() - 1).collect();
                            c.compact(&keep);
                        }
                        caches[ci] = c;
                    }
                    _ => {} // no mutation this step (pure re-gather)
                }
                // Random batch composition + joint cap each quantum.
                let n_live = g.usize_in(1, caches.len());
                let rows = n_live + g.usize_in(0, 2);
                let need = caches[..n_live].iter().map(|c| c.len()).max().unwrap();
                let cap = caps
                    .iter()
                    .copied()
                    .filter(|&c| c >= need)
                    .min()
                    .unwrap_or(need)
                    .max(need);
                for c in caches[..n_live].iter_mut() {
                    if c.cap() < cap {
                        c.grow(cap);
                    }
                }
                let refs: Vec<&LayerCache> = caches[..n_live].iter().collect();
                buf.fill(&refs, rows, cap);
                let (ko, vo) = oracle(&refs, rows, cap);
                let per = h_n * cap * dh;
                assert_eq!(
                    &buf.k()[..rows * per],
                    &ko[..],
                    "K slab diverged from the stateless gather"
                );
                assert_eq!(&buf.v()[..rows * per], &vo[..], "V slab diverged");
            }
        });
    }

    #[test]
    fn steady_state_decode_takes_the_delta_path() {
        let pool = BlockPool::new();
        let (h_n, dh) = (2, 3);
        let cap = 2 * BLOCK_TOKENS;
        let mut a = LayerCache::new_in(pool.clone(), h_n, dh, cap);
        let mut b = LayerCache::new_in(pool.clone(), h_n, dh, cap);
        for i in 0..5 {
            a.append(&[i as f32; 6], &[-(i as f32); 6], i as i32);
            b.append(&[10.0 + i as f32; 6], &[-10.0 - (i as f32); 6], i as i32);
        }
        let mut buf = GatherBuf::new();
        let s0 = buf.fill(&[&a, &b], 3, cap);
        assert_eq!((s0.delta_rows, s0.full_rows), (0, 2), "first fill is all full gathers");
        // One appended row per generation: both rows go delta.
        a.append(&[99.0; 6], &[-99.0; 6], 5);
        b.append(&[88.0; 6], &[-88.0; 6], 5);
        let s1 = buf.fill(&[&a, &b], 3, cap);
        assert_eq!((s1.delta_rows, s1.full_rows), (2, 0), "steady state must delta");
        // A compaction invalidates exactly that generation's row.
        a.compact(&[0, 2, 4]);
        let s2 = buf.fill(&[&a, &b], 3, cap);
        assert_eq!((s2.delta_rows, s2.full_rows), (1, 1));
        // Unchanged batch: zero-row deltas, still correct.
        let s3 = buf.fill(&[&a, &b], 3, cap);
        assert_eq!((s3.delta_rows, s3.full_rows), (2, 0));
        let (ko, vo) = {
            let mut k = Vec::new();
            let mut v = Vec::new();
            LayerCache::padded_kv_batch_into(&[&a, &b], 3, cap, &mut k, &mut v);
            (k, v)
        };
        let per = h_n * cap * dh;
        assert_eq!(&buf.k()[..3 * per], &ko[..]);
        assert_eq!(&buf.v()[..3 * per], &vo[..]);
    }

    #[test]
    fn shrinking_batch_zeroes_vacated_rows() {
        let pool = BlockPool::new();
        let cap = BLOCK_TOKENS;
        let mut a = LayerCache::new_in(pool.clone(), 1, 2, cap);
        let mut b = LayerCache::new_in(pool.clone(), 1, 2, cap);
        for i in 0..4 {
            a.append(&[1.0 + i as f32; 2], &[-1.0; 2], i as i32);
            b.append(&[5.0 + i as f32; 2], &[-5.0; 2], i as i32);
        }
        let mut buf = GatherBuf::new();
        buf.fill(&[&a, &b], 2, cap);
        // b leaves the batch; its old row must read zero again.
        buf.fill(&[&a], 2, cap);
        let per = cap * 2;
        assert!(buf.k()[per..2 * per].iter().all(|&x| x == 0.0), "vacated row re-zeroed");
        assert!(buf.v()[per..2 * per].iter().all(|&x| x == 0.0));
        let (ko, _) = oracle(&[&a], 2, cap);
        assert_eq!(&buf.k()[..2 * per], &ko[..]);
    }
}
