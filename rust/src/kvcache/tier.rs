//! Tiered KV: host-RAM + disk spill tiers below the device-resident
//! [`super::PrefixCache`], with a budgeted background pruner.
//!
//! FastAV's positional global pruning makes warm AV-prefix entries the
//! cheapest token source in the system (a hit skips ≥ 90% of front
//! prefill), yet plain LRU eviction *discards* them — a multi-tenant
//! working set larger than the device byte budget thrashes straight
//! back to full AV prefill. The tiered store turns that hard capacity
//! limit into a latency gradient:
//!
//! ```text
//!   device PrefixCache ──evict──► pending queue ──pruner──► RAM tier
//!        ▲                  (Arc move, O(1))        (serialize, budgeted)
//!        │ promote (deserialize + resume replay)        │ RAM over budget
//!        └──────────◄── RAM tier ◄──── disk tier ◄──────┘ (spill, budgeted)
//! ```
//!
//! **Demotion never blocks a serving quantum.** The eviction hook in
//! [`super::PrefixCache::insert`] only moves the evicted entry's `Arc`
//! into the *pending* queue — no serialization, no I/O, O(1) under a
//! short lock. The background pruner drains pending → RAM → disk with
//! per-run work budgets ([`PruneBudget`]: max entries and max payload
//! bytes per run) and a checkpointed cursor ([`PruneCursor`]) so an
//! exhausted run resumes exactly where it stopped — the same
//! incremental-prune shape as reth's `PrunerBuilder`
//! (`delete_limit_per_block` / `prune_max_blocks_per_run`).
//!
//! **Promotion is the paying request's own work.** A device miss in
//! [`super::PrefixCache::lookup_exact_where`] consults the tiers; a hit
//! deserializes the entry back into pool blocks (a memcpy per row, far
//! cheaper than recomputing front prefill), re-inserts it device-side,
//! and the request resumes through the unchanged resume path. The
//! promotion cost is recorded in `fastav_tier_promote_seconds` and as a
//! `tier_promote` trace segment.
//!
//! **Serialization format** ([`SerializedEntry::encode`]): a little-
//! endian record `magic "FVT1" | cfg | token list | prefix_len |
//! keep_positions | h_keep | full layers | keep layers`, each layer as
//! `n_heads | d_head | cap | rows × (pos, k[H·dh], v[H·dh])`. The entry
//! carries its own identity (`cfg` + tokens), so a promoted entry
//! re-enters the device trie under exactly the key it was evicted from.
//! The disk tier is an append-only record file read/written with
//! positioned I/O (`pread`/`pwrite` through the OS page cache — this
//! image has no mmap crate; the access pattern is identical), compacted
//! in place by the pruner when the dead-record ratio passes 1/2.

use std::collections::{BTreeMap, HashMap, VecDeque};
use std::fs::{File, OpenOptions};
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Instant;

use crate::metrics::{labeled, Counter, Gauge, Histogram, Registry};

use super::block::BlockPool;
use super::prefix::{hash_mix, hash_tokens};
use super::{LayerCache, PrefixEntry};

// ------------------------------------------------------------- codec

/// Record magic + version (`"FVT1"`). Bump on any layout change: a
/// decoder that sees a foreign magic drops the record instead of
/// misreading floats.
const MAGIC: u32 = 0x4656_5431;

/// One layer's rows in flat, pool-independent form.
#[derive(Debug, Clone, PartialEq)]
pub struct SerializedLayer {
    pub n_heads: usize,
    pub d_head: usize,
    pub cap: usize,
    pub positions: Vec<i32>,
    /// `[rows × n_heads × d_head]`, row-major (token, then head).
    pub k: Vec<f32>,
    pub v: Vec<f32>,
}

impl SerializedLayer {
    /// Flatten a live [`LayerCache`] (reads rows under the pool lock).
    pub fn from_cache(c: &LayerCache) -> SerializedLayer {
        let (h, dh) = (c.n_heads, c.d_head);
        let n = c.len();
        let mut k = Vec::with_capacity(n * h * dh);
        let mut v = Vec::with_capacity(n * h * dh);
        for i in 0..n {
            for head in 0..h {
                k.extend_from_slice(&c.k_row(head, i));
                v.extend_from_slice(&c.v_row(head, i));
            }
        }
        SerializedLayer {
            n_heads: h,
            d_head: dh,
            cap: c.cap(),
            positions: c.positions().to_vec(),
            k,
            v,
        }
    }

    /// Rebuild a paged cache in `pool` (fresh blocks, refcount 1).
    pub fn to_cache(&self, pool: &BlockPool) -> LayerCache {
        let w = self.n_heads * self.d_head;
        let mut c = LayerCache::new_in(
            pool.clone(),
            self.n_heads,
            self.d_head,
            self.cap.max(self.positions.len()).max(1),
        );
        for (i, &pos) in self.positions.iter().enumerate() {
            c.append(&self.k[i * w..(i + 1) * w], &self.v[i * w..(i + 1) * w], pos);
        }
        c
    }

    fn payload_bytes(&self) -> usize {
        (self.k.len() + self.v.len()) * 4 + self.positions.len() * 4
    }
}

/// A [`PrefixEntry`] in pool-independent form, carrying its own cache
/// identity (`cfg` + `tokens`) so promotion re-inserts under the exact
/// trie key the entry was demoted from.
#[derive(Debug, Clone, PartialEq)]
pub struct SerializedEntry {
    pub cfg: u64,
    pub tokens: Vec<u32>,
    pub prefix_len: usize,
    pub keep_positions: Vec<i32>,
    pub h_keep: Vec<f32>,
    pub full_layers: Vec<SerializedLayer>,
    pub keep_layers: Vec<SerializedLayer>,
}

impl SerializedEntry {
    /// Flatten a live entry (the demotion direction).
    pub fn from_entry(cfg: u64, tokens: &[u32], e: &PrefixEntry) -> SerializedEntry {
        SerializedEntry {
            cfg,
            tokens: tokens.to_vec(),
            prefix_len: e.prefix_len,
            keep_positions: e.keep_positions.clone(),
            h_keep: e.h_keep.clone(),
            full_layers: e.full_layers.iter().map(SerializedLayer::from_cache).collect(),
            keep_layers: e.keep_layers.iter().map(SerializedLayer::from_cache).collect(),
        }
    }

    /// Rebuild a device-resident entry in `pool` (the promotion
    /// direction). `bytes` is recomputed by `finalize`, so the promoted
    /// entry's accounting reflects its *new* block allocation.
    pub fn to_entry(&self, pool: &BlockPool) -> PrefixEntry {
        PrefixEntry {
            prefix_len: self.prefix_len,
            full_layers: self.full_layers.iter().map(|l| l.to_cache(pool)).collect(),
            keep_layers: self.keep_layers.iter().map(|l| l.to_cache(pool)).collect(),
            h_keep: self.h_keep.clone(),
            keep_positions: self.keep_positions.clone(),
            bytes: 0,
        }
        .finalize()
    }

    /// The exact-lookup key this entry answers for (mirrors
    /// [`super::PrefixCache`]'s `hash_mix(cfg, hash_tokens(tokens))`).
    pub fn entry_key(&self) -> u64 {
        hash_mix(&[self.cfg, hash_tokens(0, &self.tokens)])
    }

    /// Approximate payload bytes held by this serialized form (the
    /// tier-budget accounting unit).
    pub fn payload_bytes(&self) -> usize {
        self.h_keep.len() * 4
            + self.keep_positions.len() * 4
            + self.tokens.len() * 4
            + self
                .full_layers
                .iter()
                .chain(self.keep_layers.iter())
                .map(|l| l.payload_bytes())
                .sum::<usize>()
    }

    /// Encode to the `FVT1` little-endian record.
    pub fn encode(&self) -> Vec<u8> {
        let mut out = Vec::with_capacity(64 + self.payload_bytes());
        put_u32(&mut out, MAGIC);
        put_u64(&mut out, self.cfg);
        put_u64(&mut out, self.tokens.len() as u64);
        for &t in &self.tokens {
            put_u32(&mut out, t);
        }
        put_u64(&mut out, self.prefix_len as u64);
        put_u64(&mut out, self.keep_positions.len() as u64);
        for &p in &self.keep_positions {
            put_u32(&mut out, p as u32);
        }
        put_u64(&mut out, self.h_keep.len() as u64);
        for &x in &self.h_keep {
            put_u32(&mut out, x.to_bits());
        }
        for layers in [&self.full_layers, &self.keep_layers] {
            put_u64(&mut out, layers.len() as u64);
            for l in layers {
                put_u64(&mut out, l.n_heads as u64);
                put_u64(&mut out, l.d_head as u64);
                put_u64(&mut out, l.cap as u64);
                put_u64(&mut out, l.positions.len() as u64);
                for &p in &l.positions {
                    put_u32(&mut out, p as u32);
                }
                for &x in &l.k {
                    put_u32(&mut out, x.to_bits());
                }
                for &x in &l.v {
                    put_u32(&mut out, x.to_bits());
                }
            }
        }
        out
    }

    /// Decode an `FVT1` record; `None` on truncation or foreign magic
    /// (a torn disk record drops instead of resurrecting garbage rows).
    pub fn decode(buf: &[u8]) -> Option<SerializedEntry> {
        let mut r = Reader { buf, at: 0 };
        if r.u32()? != MAGIC {
            return None;
        }
        let cfg = r.u64()?;
        let n_tokens = r.u64()? as usize;
        let tokens = r.u32_vec(n_tokens)?;
        let prefix_len = r.u64()? as usize;
        let n_keep = r.u64()? as usize;
        let keep_positions = r.i32_vec(n_keep)?;
        let n_h = r.u64()? as usize;
        let h_keep = r.f32_vec(n_h)?;
        let mut groups = Vec::with_capacity(2);
        for _ in 0..2 {
            let n_layers = r.u64()? as usize;
            // Layer counts are small (the front half of a model);
            // reject absurd values before allocating.
            if n_layers > 4096 {
                return None;
            }
            let mut layers = Vec::with_capacity(n_layers);
            for _ in 0..n_layers {
                let n_heads = r.u64()? as usize;
                let d_head = r.u64()? as usize;
                let cap = r.u64()? as usize;
                let rows = r.u64()? as usize;
                let positions = r.i32_vec(rows)?;
                let w = rows.checked_mul(n_heads.checked_mul(d_head)?)?;
                let k = r.f32_vec(w)?;
                let v = r.f32_vec(w)?;
                layers.push(SerializedLayer { n_heads, d_head, cap, positions, k, v });
            }
            groups.push(layers);
        }
        let keep_layers = groups.pop()?;
        let full_layers = groups.pop()?;
        Some(SerializedEntry {
            cfg,
            tokens,
            prefix_len,
            keep_positions,
            h_keep,
            full_layers,
            keep_layers,
        })
    }
}

fn put_u32(out: &mut Vec<u8>, v: u32) {
    out.extend_from_slice(&v.to_le_bytes());
}

fn put_u64(out: &mut Vec<u8>, v: u64) {
    out.extend_from_slice(&v.to_le_bytes());
}

struct Reader<'a> {
    buf: &'a [u8],
    at: usize,
}

impl Reader<'_> {
    fn take(&mut self, n: usize) -> Option<&[u8]> {
        let end = self.at.checked_add(n)?;
        if end > self.buf.len() {
            return None;
        }
        let s = &self.buf[self.at..end];
        self.at = end;
        Some(s)
    }

    fn u32(&mut self) -> Option<u32> {
        self.take(4).map(|b| u32::from_le_bytes(b.try_into().unwrap()))
    }

    fn u64(&mut self) -> Option<u64> {
        self.take(8).map(|b| u64::from_le_bytes(b.try_into().unwrap()))
    }

    fn u32_vec(&mut self, n: usize) -> Option<Vec<u32>> {
        let b = self.take(n.checked_mul(4)?)?;
        Some(b.chunks_exact(4).map(|c| u32::from_le_bytes(c.try_into().unwrap())).collect())
    }

    fn i32_vec(&mut self, n: usize) -> Option<Vec<i32>> {
        Some(self.u32_vec(n)?.into_iter().map(|v| v as i32).collect())
    }

    fn f32_vec(&mut self, n: usize) -> Option<Vec<f32>> {
        Some(self.u32_vec(n)?.into_iter().map(f32::from_bits).collect())
    }
}

// ----------------------------------------------------------- config

/// Tier sizing. Both tiers optional: `ram_bytes == 0` disables the RAM
/// tier (pending demotions spill straight to disk, or drop if no disk
/// either); `disk_path == None` disables the disk tier.
#[derive(Debug, Clone, Default)]
pub struct TierConfig {
    /// Host-RAM slab budget in bytes (serialized payload accounting).
    pub ram_bytes: usize,
    /// Backing file for the disk tier; created (truncated) on startup.
    pub disk_path: Option<PathBuf>,
    /// Disk-tier live-payload budget in bytes; `0` = unlimited.
    pub disk_bytes: usize,
}

impl TierConfig {
    pub fn enabled(&self) -> bool {
        self.ram_bytes > 0 || self.disk_path.is_some()
    }
}

/// Per-run work budget for [`TieredStore::prune_run`]: the run stops as
/// soon as either limit is reached and checkpoints its cursor, so one
/// run's cost is bounded no matter how deep the backlog is.
#[derive(Debug, Clone, Copy)]
pub struct PruneBudget {
    /// Max entries moved (demoted, spilled, or dropped) per run.
    pub max_entries: usize,
    /// Max serialized payload bytes moved per run.
    pub max_bytes: usize,
}

impl Default for PruneBudget {
    fn default() -> PruneBudget {
        PruneBudget { max_entries: 32, max_bytes: 64 << 20 }
    }
}

/// Where the pruner's walk stopped when its budget ran out; the next
/// run resumes from here instead of rescanning from the front.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct PruneCursor {
    /// Stage the last run was in when it exhausted its budget:
    /// 0 = pending drain, 1 = RAM spill, 2 = disk enforcement/compact.
    pub stage: u8,
    /// RAM-tier sequence number the spill walk resumes from.
    pub ram_seq: u64,
}

/// What one [`TieredStore::prune_run`] actually did (pruner-budget
/// tests assert against this, and `/v1/pool` reports the totals).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct PruneRunReport {
    /// Entries moved this run (demoted + spilled + dropped).
    pub entries: usize,
    /// Serialized payload bytes moved this run.
    pub bytes: usize,
    pub demoted_ram: usize,
    pub spilled_disk: usize,
    pub dropped: usize,
    /// Dead file bytes reclaimed by a disk compaction this run.
    pub compacted_bytes: usize,
    /// True when the run stopped on budget with work left (the next
    /// run resumes from the checkpointed cursor).
    pub exhausted: bool,
}

/// Point-in-time tier accounting (the `/v1/pool` tier block).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct TierStats {
    /// Device-evicted entries staged but not yet serialized.
    pub pending_entries: usize,
    pub pending_bytes: usize,
    pub ram_entries: usize,
    pub ram_bytes: usize,
    pub disk_entries: usize,
    /// Live serialized bytes on disk (excludes dead records).
    pub disk_bytes: usize,
    /// Backing-file size including dead records awaiting compaction.
    pub disk_file_bytes: usize,
    pub demotions_ram: u64,
    pub demotions_disk: u64,
    pub promotions_ram: u64,
    pub promotions_disk: u64,
    pub drops_ram: u64,
    pub drops_disk: u64,
    pub prune_runs: u64,
    pub prune_entries: u64,
    pub prune_bytes: u64,
    pub cursor: PruneCursor,
}

/// Per-tier flush accounting (`POST /v1/cache/flush` response).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct TierFlush {
    pub pending_entries: usize,
    pub pending_bytes: usize,
    pub ram_entries: usize,
    pub ram_bytes: usize,
    pub disk_entries: usize,
    pub disk_bytes: usize,
}

/// Which tier satisfied a promotion (metrics labels; the pending queue
/// is host-RAM-resident, so it reports under `ram`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TierHit {
    /// Straight from the pending queue — the entry was never
    /// serialized, so the `Arc` moves back without a rebuild.
    Pending,
    Ram,
    Disk,
}

// ------------------------------------------------------------- tiers

/// An entry staged for demotion: the device cache's evicted `Arc` plus
/// the identity needed to serialize it later.
struct Pending {
    cfg: u64,
    tokens: Vec<u32>,
    entry: Arc<PrefixEntry>,
}

/// RAM slab record. `seq` orders the tier for LRU spill and gives the
/// pruner cursor something stable to resume from.
struct RamRec {
    seq: u64,
    entry: Arc<SerializedEntry>,
    bytes: usize,
}

#[derive(Default)]
struct RamTier {
    /// entry key → record (serialized payload kept in host RAM).
    map: HashMap<u64, RamRec>,
    /// seq → entry key, the spill/walk order (oldest first).
    order: BTreeMap<u64, u64>,
    bytes: usize,
    next_seq: u64,
}

impl RamTier {
    fn insert(&mut self, key: u64, entry: Arc<SerializedEntry>, bytes: usize) {
        self.remove(key);
        let seq = self.next_seq;
        self.next_seq += 1;
        self.order.insert(seq, key);
        self.map.insert(key, RamRec { seq, entry, bytes });
        self.bytes += bytes;
    }

    fn remove(&mut self, key: u64) -> Option<RamRec> {
        let rec = self.map.remove(&key)?;
        self.order.remove(&rec.seq);
        self.bytes -= rec.bytes;
        Some(rec)
    }
}

/// Disk record index entry: where one serialized entry lives in the
/// backing file.
struct DiskRec {
    offset: u64,
    len: usize,
    /// Decoded-payload accounting bytes (mirrors the RAM unit so the
    /// budgets compare like-for-like).
    bytes: usize,
    seq: u64,
}

struct DiskTier {
    file: File,
    path: PathBuf,
    map: HashMap<u64, DiskRec>,
    order: BTreeMap<u64, u64>,
    /// Live payload bytes (budget accounting).
    bytes: usize,
    /// Next append offset == file length.
    tail: u64,
    /// File bytes owned by deleted/overwritten records.
    dead_file_bytes: u64,
    next_seq: u64,
}

impl DiskTier {
    fn open(path: &Path) -> std::io::Result<DiskTier> {
        let file = OpenOptions::new()
            .read(true)
            .write(true)
            .create(true)
            .truncate(true)
            .open(path)?;
        Ok(DiskTier {
            file,
            path: path.to_path_buf(),
            map: HashMap::new(),
            order: BTreeMap::new(),
            bytes: 0,
            tail: 0,
            dead_file_bytes: 0,
            next_seq: 0,
        })
    }

    fn write_record(&mut self, key: u64, encoded: &[u8], payload_bytes: usize) -> bool {
        use std::os::unix::fs::FileExt;
        // Length-prefixed record so compaction can walk the file.
        let mut rec = Vec::with_capacity(8 + encoded.len());
        put_u64(&mut rec, encoded.len() as u64);
        rec.extend_from_slice(encoded);
        let offset = self.tail;
        if self.file.write_at(&rec, offset).map(|n| n == rec.len()) != Ok(true) {
            return false;
        }
        self.tail += rec.len() as u64;
        if let Some(old) = self.map.remove(&key) {
            self.order.remove(&old.seq);
            self.bytes -= old.bytes;
            self.dead_file_bytes += 8 + old.len as u64;
        }
        let seq = self.next_seq;
        self.next_seq += 1;
        self.order.insert(seq, key);
        self.map.insert(key, DiskRec { offset, len: encoded.len(), bytes: payload_bytes, seq });
        self.bytes += payload_bytes;
        true
    }

    fn read_record(&self, key: u64) -> Option<Vec<u8>> {
        use std::os::unix::fs::FileExt;
        let rec = self.map.get(&key)?;
        let mut buf = vec![0u8; rec.len];
        self.file.read_exact_at(&mut buf, rec.offset + 8).ok()?;
        Some(buf)
    }

    fn remove(&mut self, key: u64) -> Option<usize> {
        let rec = self.map.remove(&key)?;
        self.order.remove(&rec.seq);
        self.bytes -= rec.bytes;
        self.dead_file_bytes += 8 + rec.len as u64;
        Some(rec.bytes)
    }

    /// Rewrite live records contiguously at the front of the file and
    /// truncate the dead tail. Offsets are rebuilt; seq order (and so
    /// the LRU drop order) is preserved. Returns file bytes reclaimed.
    fn compact(&mut self) -> u64 {
        use std::os::unix::fs::FileExt;
        if self.dead_file_bytes == 0 {
            return 0;
        }
        let before = self.tail;
        let mut new_tail: u64 = 0;
        // Walk in seq order so relative ages survive the rewrite.
        let keys: Vec<u64> = self.order.values().copied().collect();
        for key in keys {
            let (offset, len) = {
                let rec = &self.map[&key];
                (rec.offset, rec.len)
            };
            let mut rec_buf = vec![0u8; 8 + len];
            if self.file.read_exact_at(&mut rec_buf, offset).is_err() {
                continue;
            }
            if self.file.write_at(&rec_buf, new_tail).map(|n| n == rec_buf.len()) != Ok(true) {
                continue;
            }
            self.map.get_mut(&key).expect("live key").offset = new_tail;
            new_tail += rec_buf.len() as u64;
        }
        let _ = self.file.set_len(new_tail);
        self.tail = new_tail;
        self.dead_file_bytes = 0;
        before.saturating_sub(new_tail)
    }
}

// ------------------------------------------------------------- store

/// Tier counters kept outside the state lock (readable from any
/// thread without contending with a pruner run).
#[derive(Default)]
struct TierCounters {
    demotions_ram: AtomicU64,
    demotions_disk: AtomicU64,
    promotions_ram: AtomicU64,
    promotions_disk: AtomicU64,
    drops_ram: AtomicU64,
    drops_disk: AtomicU64,
    prune_runs: AtomicU64,
    prune_entries: AtomicU64,
    prune_bytes: AtomicU64,
}

/// Metric handles bound by [`TieredStore::bind_metrics`].
struct TierSinks {
    demotions_ram: Arc<Counter>,
    demotions_disk: Arc<Counter>,
    promotions_ram: Arc<Counter>,
    promotions_disk: Arc<Counter>,
    drops_ram: Arc<Counter>,
    drops_disk: Arc<Counter>,
    bytes_ram: Arc<Gauge>,
    bytes_disk: Arc<Gauge>,
    pending_g: Arc<Gauge>,
    promote_hist: Arc<Histogram>,
}

struct TierState {
    pending: VecDeque<Pending>,
    pending_bytes: usize,
    ram: RamTier,
    disk: Option<DiskTier>,
    cursor: PruneCursor,
}

/// The two-level spill store one [`super::PrefixCache`] demotes into
/// and promotes from. Thread-safe (`&self` everywhere); shared between
/// the replica threads (stage/promote) and the pruner thread
/// (prune_run) behind an `Arc`.
pub struct TieredStore {
    cfg: TierConfig,
    state: Mutex<TierState>,
    counters: TierCounters,
    sinks: Mutex<Option<TierSinks>>,
}

impl TieredStore {
    /// Build the store; creates (truncates) the disk backing file when
    /// one is configured. A disk path that cannot be opened disables
    /// the disk tier rather than failing the pool.
    pub fn new(cfg: TierConfig) -> TieredStore {
        let disk = cfg.disk_path.as_deref().and_then(|p| match DiskTier::open(p) {
            Ok(d) => Some(d),
            Err(e) => {
                eprintln!("tiered-kv: disk tier disabled ({}: {})", p.display(), e);
                None
            }
        });
        TieredStore {
            cfg,
            state: Mutex::new(TierState {
                pending: VecDeque::new(),
                pending_bytes: 0,
                ram: RamTier::default(),
                disk,
                cursor: PruneCursor::default(),
            }),
            counters: TierCounters::default(),
            sinks: Mutex::new(None),
        }
    }

    pub fn config(&self) -> &TierConfig {
        &self.cfg
    }

    /// Bind the `fastav_tier_*` series (counters labeled
    /// `tier="ram"|"disk"`, byte gauges, promotion-latency histogram).
    pub fn bind_metrics(&self, metrics: &Registry) {
        *self.sinks.lock().unwrap() = Some(TierSinks {
            demotions_ram: metrics.counter(&labeled("fastav_tier_demotions_total", "tier", "ram")),
            demotions_disk: metrics
                .counter(&labeled("fastav_tier_demotions_total", "tier", "disk")),
            promotions_ram: metrics
                .counter(&labeled("fastav_tier_promotions_total", "tier", "ram")),
            promotions_disk: metrics
                .counter(&labeled("fastav_tier_promotions_total", "tier", "disk")),
            drops_ram: metrics.counter(&labeled("fastav_tier_drops_total", "tier", "ram")),
            drops_disk: metrics.counter(&labeled("fastav_tier_drops_total", "tier", "disk")),
            bytes_ram: metrics.gauge(&labeled("fastav_tier_bytes", "tier", "ram")),
            bytes_disk: metrics.gauge(&labeled("fastav_tier_bytes", "tier", "disk")),
            pending_g: metrics.gauge("fastav_tier_pending_entries"),
            promote_hist: metrics.histogram("fastav_tier_promote_seconds"),
        });
        self.refresh_gauges();
    }

    fn refresh_gauges(&self) {
        let sinks = self.sinks.lock().unwrap();
        if let Some(s) = sinks.as_ref() {
            let st = self.state.lock().unwrap();
            s.bytes_ram.set(st.ram.bytes as u64);
            s.bytes_disk.set(st.disk.as_ref().map_or(0, |d| d.bytes) as u64);
            s.pending_g.set(st.pending.len() as u64);
        }
    }

    /// Stage a device-evicted entry for demotion. O(1): moves the `Arc`
    /// into the pending queue — never serializes on the caller's
    /// (replica) thread. Called by [`super::PrefixCache`] *after* its
    /// inner lock is released.
    pub fn stage_demotion(&self, cfg: u64, tokens: Vec<u32>, entry: Arc<PrefixEntry>) {
        {
            let mut st = self.state.lock().unwrap();
            st.pending_bytes += entry.bytes;
            st.pending.push_back(Pending { cfg, tokens, entry });
        }
        self.refresh_gauges();
    }

    /// Exact-key probe across all tiers without promoting (the
    /// admission estimate path — an index lookup, no deserialization or
    /// file I/O). Returns the entry's device-payload byte estimate.
    pub fn peek(&self, cfg: u64, tokens: &[u32]) -> Option<usize> {
        let key = hash_mix(&[cfg, hash_tokens(0, tokens)]);
        let st = self.state.lock().unwrap();
        if let Some(p) = st.pending.iter().find(|p| p.cfg == cfg && p.tokens == tokens) {
            return Some(p.entry.bytes);
        }
        if let Some(rec) = st.ram.map.get(&key) {
            return Some(rec.bytes);
        }
        if let Some(d) = st.disk.as_ref() {
            if let Some(rec) = d.map.get(&key) {
                return Some(rec.bytes);
            }
        }
        None
    }

    /// Promote the entry for (`cfg`, `tokens`) back toward the device
    /// tier: from the pending queue the original `Arc` moves back
    /// untouched; from RAM/disk the serialized form is rebuilt into
    /// `pool` blocks. Records the promotion latency histogram and a
    /// `tier_promote` trace segment. The promoted entry leaves the
    /// spill tier (the device cache re-owns it; re-eviction re-demotes).
    pub fn promote(
        &self,
        pool: &BlockPool,
        cfg: u64,
        tokens: &[u32],
    ) -> Option<(Arc<PrefixEntry>, TierHit)> {
        let t0 = Instant::now();
        let seg_t0 = crate::trace::seg_begin();
        let key = hash_mix(&[cfg, hash_tokens(0, tokens)]);
        let found = self.take_for_promotion(key, cfg, tokens);
        let out = match found {
            Some(Promoted::Device(entry)) => Some((entry, TierHit::Pending)),
            Some(Promoted::Serialized(se, hit)) => {
                Some((Arc::new(se.to_entry(pool)), hit))
            }
            None => None,
        };
        if let Some((_, hit)) = out.as_ref() {
            let sinks = self.sinks.lock().unwrap();
            match hit {
                TierHit::Pending | TierHit::Ram => {
                    self.counters.promotions_ram.fetch_add(1, Ordering::Relaxed);
                    if let Some(s) = sinks.as_ref() {
                        s.promotions_ram.inc();
                    }
                }
                TierHit::Disk => {
                    self.counters.promotions_disk.fetch_add(1, Ordering::Relaxed);
                    if let Some(s) = sinks.as_ref() {
                        s.promotions_disk.inc();
                    }
                }
            }
            if let Some(s) = sinks.as_ref() {
                s.promote_hist.observe(t0.elapsed().as_secs_f64());
            }
        }
        crate::trace::seg_end("tier_promote", None, seg_t0);
        self.refresh_gauges();
        out
    }

    fn take_for_promotion(&self, key: u64, cfg: u64, tokens: &[u32]) -> Option<Promoted> {
        let mut st = self.state.lock().unwrap();
        if let Some(i) = st.pending.iter().position(|p| p.cfg == cfg && p.tokens == tokens) {
            let p = st.pending.remove(i).expect("index just found");
            st.pending_bytes -= p.entry.bytes;
            return Some(Promoted::Device(p.entry));
        }
        if let Some(rec) = st.ram.remove(key) {
            // Sole owner after removal in the common case; clone the
            // payload only if a concurrent reader still holds the Arc.
            let se = Arc::try_unwrap(rec.entry).unwrap_or_else(|a| (*a).clone());
            return Some(Promoted::Serialized(se, TierHit::Ram));
        }
        let buf = st.disk.as_ref().and_then(|d| d.read_record(key));
        if let Some(buf) = buf {
            if let Some(se) = SerializedEntry::decode(&buf) {
                if let Some(d) = st.disk.as_mut() {
                    d.remove(key);
                }
                return Some(Promoted::Serialized(se, TierHit::Disk));
            }
            // Torn record: drop it so the key stops matching.
            if let Some(d) = st.disk.as_mut() {
                d.remove(key);
            }
            self.counters.drops_disk.fetch_add(1, Ordering::Relaxed);
        }
        None
    }

    /// One budgeted pruner run (the reth `PrunerBuilder` shape): drain
    /// pending demotions into the RAM tier, spill the RAM tier's oldest
    /// entries to disk while RAM is over budget, enforce the disk
    /// budget by dropping oldest, and compact the disk file when more
    /// than half of it is dead. Every unit of work is charged against
    /// `budget`; when a limit is hit the run checkpoints its cursor and
    /// returns `exhausted: true`, and the next run resumes from the
    /// checkpoint instead of rescanning.
    pub fn prune_run(&self, budget: PruneBudget) -> PruneRunReport {
        let mut report = PruneRunReport::default();
        let budget = PruneBudget {
            max_entries: budget.max_entries.max(1),
            max_bytes: budget.max_bytes.max(1),
        };
        let mut st = self.state.lock().unwrap();
        let start_stage = st.cursor.stage;

        // Stage 0: pending → RAM (serialize off the hot path). A
        // cursor parked in a later stage skips pending this run — the
        // walk continues where it stopped, like reth's segment order.
        if start_stage == 0 {
            while !self.budget_hit(&report, budget) {
                let Some(p) = st.pending.pop_front() else { break };
                st.pending_bytes -= p.entry.bytes;
                let se = SerializedEntry::from_entry(p.cfg, &p.tokens, &p.entry);
                let bytes = se.payload_bytes();
                let key = se.entry_key();
                report.entries += 1;
                report.bytes += bytes;
                if self.cfg.ram_bytes > 0 {
                    st.ram.insert(key, Arc::new(se), bytes);
                    report.demoted_ram += 1;
                    self.count_demotion_ram();
                } else if st.disk.is_some() {
                    let encoded = se.encode();
                    let d = st.disk.as_mut().expect("checked above");
                    if d.write_record(key, &encoded, bytes) {
                        report.spilled_disk += 1;
                        self.count_demotion_disk();
                    } else {
                        report.dropped += 1;
                        self.count_drop(TierHit::Disk);
                    }
                } else {
                    report.dropped += 1;
                    self.count_drop(TierHit::Ram);
                }
            }
            if !st.pending.is_empty() {
                // Budget ran out mid-stage; resume here next run.
                st.cursor = PruneCursor { stage: 0, ram_seq: 0 };
                report.exhausted = true;
                drop(st);
                self.finish_run(&report);
                return report;
            }
        }

        // Stage 1: RAM over budget → spill oldest to disk (or drop when
        // no disk tier). The cursor's ram_seq resumes the walk at the
        // first unprocessed sequence number.
        let resume_seq = if start_stage == 1 { st.cursor.ram_seq } else { 0 };
        while st.ram.bytes > self.cfg.ram_bytes && !self.budget_hit(&report, budget) {
            let Some((_, &key)) = st.ram.order.range(resume_seq..).next() else { break };
            let Some(rec) = st.ram.remove(key) else { break };
            report.entries += 1;
            report.bytes += rec.bytes;
            if st.disk.is_some() {
                let encoded = rec.entry.encode();
                let d = st.disk.as_mut().expect("checked above");
                if d.write_record(key, &encoded, rec.bytes) {
                    report.spilled_disk += 1;
                    self.count_demotion_disk();
                } else {
                    report.dropped += 1;
                    self.count_drop(TierHit::Disk);
                }
            } else {
                report.dropped += 1;
                self.count_drop(TierHit::Ram);
            }
        }
        if st.ram.bytes > self.cfg.ram_bytes {
            let next = st.ram.order.keys().next().copied().unwrap_or(0);
            st.cursor = PruneCursor { stage: 1, ram_seq: next };
            report.exhausted = true;
            drop(st);
            self.finish_run(&report);
            return report;
        }

        // Stage 2: disk budget enforcement (drop oldest) + compaction.
        if let Some(d) = st.disk.as_mut() {
            if self.cfg.disk_bytes > 0 {
                while d.bytes > self.cfg.disk_bytes && !self.budget_hit(&report, budget) {
                    let Some((_, &key)) = d.order.iter().next() else { break };
                    if let Some(bytes) = d.remove(key) {
                        report.entries += 1;
                        report.bytes += bytes;
                        report.dropped += 1;
                        self.count_drop(TierHit::Disk);
                    }
                }
            }
            let over = self.cfg.disk_bytes > 0 && d.bytes > self.cfg.disk_bytes;
            if !over && d.tail > 0 && d.dead_file_bytes * 2 > d.tail {
                report.compacted_bytes = d.compact() as usize;
            }
            if over {
                st.cursor = PruneCursor { stage: 2, ram_seq: 0 };
                report.exhausted = true;
                drop(st);
                self.finish_run(&report);
                return report;
            }
        }

        st.cursor = PruneCursor::default();
        drop(st);
        self.finish_run(&report);
        report
    }

    fn budget_hit(&self, report: &PruneRunReport, budget: PruneBudget) -> bool {
        report.entries >= budget.max_entries || report.bytes >= budget.max_bytes
    }

    fn finish_run(&self, report: &PruneRunReport) {
        self.counters.prune_runs.fetch_add(1, Ordering::Relaxed);
        self.counters.prune_entries.fetch_add(report.entries as u64, Ordering::Relaxed);
        self.counters.prune_bytes.fetch_add(report.bytes as u64, Ordering::Relaxed);
        self.refresh_gauges();
    }

    fn count_demotion_ram(&self) {
        self.counters.demotions_ram.fetch_add(1, Ordering::Relaxed);
        if let Some(s) = self.sinks.lock().unwrap().as_ref() {
            s.demotions_ram.inc();
        }
    }

    fn count_demotion_disk(&self) {
        self.counters.demotions_disk.fetch_add(1, Ordering::Relaxed);
        if let Some(s) = self.sinks.lock().unwrap().as_ref() {
            s.demotions_disk.inc();
        }
    }

    fn count_drop(&self, tier: TierHit) {
        let sinks = self.sinks.lock().unwrap();
        match tier {
            TierHit::Disk => {
                self.counters.drops_disk.fetch_add(1, Ordering::Relaxed);
                if let Some(s) = sinks.as_ref() {
                    s.drops_disk.inc();
                }
            }
            _ => {
                self.counters.drops_ram.fetch_add(1, Ordering::Relaxed);
                if let Some(s) = sinks.as_ref() {
                    s.drops_ram.inc();
                }
            }
        }
    }

    /// Drain every tier (pending, RAM, disk), truncate the backing
    /// file, and reset the pruner checkpoint (`POST /v1/cache/flush`).
    pub fn flush(&self) -> TierFlush {
        let out = {
            let mut st = self.state.lock().unwrap();
            let out = TierFlush {
                pending_entries: st.pending.len(),
                pending_bytes: st.pending_bytes,
                ram_entries: st.ram.map.len(),
                ram_bytes: st.ram.bytes,
                disk_entries: st.disk.as_ref().map_or(0, |d| d.map.len()),
                disk_bytes: st.disk.as_ref().map_or(0, |d| d.bytes),
            };
            st.pending.clear();
            st.pending_bytes = 0;
            st.ram = RamTier::default();
            if let Some(d) = st.disk.as_mut() {
                d.map.clear();
                d.order.clear();
                d.bytes = 0;
                d.dead_file_bytes = 0;
                d.tail = 0;
                let _ = d.file.set_len(0);
            }
            st.cursor = PruneCursor::default();
            out
        };
        self.refresh_gauges();
        out
    }

    pub fn stats(&self) -> TierStats {
        let st = self.state.lock().unwrap();
        TierStats {
            pending_entries: st.pending.len(),
            pending_bytes: st.pending_bytes,
            ram_entries: st.ram.map.len(),
            ram_bytes: st.ram.bytes,
            disk_entries: st.disk.as_ref().map_or(0, |d| d.map.len()),
            disk_bytes: st.disk.as_ref().map_or(0, |d| d.bytes),
            disk_file_bytes: st.disk.as_ref().map_or(0, |d| d.tail) as usize,
            demotions_ram: self.counters.demotions_ram.load(Ordering::Relaxed),
            demotions_disk: self.counters.demotions_disk.load(Ordering::Relaxed),
            promotions_ram: self.counters.promotions_ram.load(Ordering::Relaxed),
            promotions_disk: self.counters.promotions_disk.load(Ordering::Relaxed),
            drops_ram: self.counters.drops_ram.load(Ordering::Relaxed),
            drops_disk: self.counters.drops_disk.load(Ordering::Relaxed),
            prune_runs: self.counters.prune_runs.load(Ordering::Relaxed),
            prune_entries: self.counters.prune_entries.load(Ordering::Relaxed),
            prune_bytes: self.counters.prune_bytes.load(Ordering::Relaxed),
            cursor: st.cursor,
        }
    }
}

impl Drop for TieredStore {
    fn drop(&mut self) {
        // Remove the backing file: tier contents are a cache of
        // recomputable state, never durable data.
        if let Some(d) = self.state.get_mut().ok().and_then(|s| s.disk.take()) {
            drop(d.file);
            let _ = std::fs::remove_file(&d.path);
        }
    }
}

enum Promoted {
    /// Intercepted in the pending queue, still in device form.
    Device(Arc<PrefixEntry>),
    Serialized(SerializedEntry, TierHit),
}

#[cfg(test)]
mod tests {
    use super::*;

    fn entry(pool: &BlockPool, rows: usize, salt: f32) -> PrefixEntry {
        let mut full = LayerCache::new_in(pool.clone(), 2, 3, rows.max(1));
        let mut keep = LayerCache::new_in(pool.clone(), 2, 3, rows.max(1));
        for i in 0..rows {
            let k: Vec<f32> = (0..6).map(|j| salt + (i * 6 + j) as f32).collect();
            let v: Vec<f32> = (0..6).map(|j| -(salt + (i * 6 + j) as f32)).collect();
            full.append(&k, &v, i as i32);
            if i % 2 == 0 {
                keep.append(&k, &v, i as i32);
            }
        }
        PrefixEntry {
            prefix_len: rows,
            full_layers: vec![full],
            keep_layers: vec![keep],
            h_keep: (0..rows).map(|i| salt * 0.5 + i as f32).collect(),
            keep_positions: (0..rows as i32).step_by(2).collect(),
            bytes: 0,
        }
        .finalize()
    }

    fn layers_equal(a: &LayerCache, b: &LayerCache) -> bool {
        if a.len() != b.len() || a.positions() != b.positions() {
            return false;
        }
        for i in 0..a.len() {
            for h in 0..a.n_heads {
                if a.k_row(h, i) != b.k_row(h, i) || a.v_row(h, i) != b.v_row(h, i) {
                    return false;
                }
            }
        }
        true
    }

    #[test]
    fn codec_roundtrip_is_lossless() {
        let pool = BlockPool::new();
        let e = entry(&pool, 7, 3.25);
        let se = SerializedEntry::from_entry(42, &[1, 2, 9], &e);
        let decoded = SerializedEntry::decode(&se.encode()).expect("decodes");
        assert_eq!(decoded, se);
        let back = decoded.to_entry(&pool);
        assert_eq!(back.prefix_len, e.prefix_len);
        assert_eq!(back.keep_positions, e.keep_positions);
        assert_eq!(back.h_keep, e.h_keep);
        assert!(layers_equal(&back.full_layers[0], &e.full_layers[0]));
        assert!(layers_equal(&back.keep_layers[0], &e.keep_layers[0]));
    }

    #[test]
    fn decode_rejects_truncation_and_foreign_magic() {
        let pool = BlockPool::new();
        let se = SerializedEntry::from_entry(1, &[5], &entry(&pool, 3, 1.0));
        let buf = se.encode();
        assert!(SerializedEntry::decode(&buf[..buf.len() - 1]).is_none());
        let mut bad = buf.clone();
        bad[0] ^= 0xFF;
        assert!(SerializedEntry::decode(&bad).is_none());
        assert!(SerializedEntry::decode(&[]).is_none());
    }

    #[test]
    fn pending_promotion_moves_arc_back_without_rebuild() {
        let pool = BlockPool::new();
        let store = TieredStore::new(TierConfig { ram_bytes: 1 << 20, ..Default::default() });
        let e = Arc::new(entry(&pool, 4, 2.0));
        store.stage_demotion(7, vec![1, 2], Arc::clone(&e));
        assert_eq!(store.stats().pending_entries, 1);
        let (back, hit) = store.promote(&pool, 7, &[1, 2]).expect("promotes");
        assert_eq!(hit, TierHit::Pending);
        assert!(Arc::ptr_eq(&back, &e), "pending promotion must not rebuild");
        assert_eq!(store.stats().pending_entries, 0);
        assert!(store.promote(&pool, 7, &[1, 2]).is_none(), "promotion removes the entry");
    }

    #[test]
    fn prune_respects_entry_budget_and_checkpoint_resumes() {
        let pool = BlockPool::new();
        let store = TieredStore::new(TierConfig { ram_bytes: 1 << 20, ..Default::default() });
        for i in 0..5u32 {
            store.stage_demotion(1, vec![i], Arc::new(entry(&pool, 3, i as f32)));
        }
        let r1 = store.prune_run(PruneBudget { max_entries: 2, max_bytes: usize::MAX });
        assert_eq!(r1.entries, 2, "run bounded by its entry budget");
        assert!(r1.exhausted);
        let s = store.stats();
        assert_eq!((s.pending_entries, s.ram_entries), (3, 2));
        assert_eq!(s.cursor.stage, 0, "checkpoint parked in the pending stage");
        let r2 = store.prune_run(PruneBudget { max_entries: 2, max_bytes: usize::MAX });
        assert_eq!(r2.entries, 2);
        let r3 = store.prune_run(PruneBudget { max_entries: 2, max_bytes: usize::MAX });
        assert_eq!(r3.entries, 1);
        assert!(!r3.exhausted);
        let s = store.stats();
        assert_eq!((s.pending_entries, s.ram_entries), (0, 5));
        assert_eq!(s.cursor, PruneCursor::default(), "finished run resets the cursor");
    }

    #[test]
    fn prune_respects_byte_budget() {
        let pool = BlockPool::new();
        let store = TieredStore::new(TierConfig { ram_bytes: 1 << 20, ..Default::default() });
        for i in 0..4u32 {
            store.stage_demotion(1, vec![i], Arc::new(entry(&pool, 8, i as f32)));
        }
        let one = SerializedEntry::from_entry(1, &[0], &entry(&pool, 8, 0.0)).payload_bytes();
        // Budget covers one entry: the run must stop at the first entry
        // whose bytes reach the limit.
        let r = store.prune_run(PruneBudget { max_entries: usize::MAX, max_bytes: one });
        assert_eq!(r.entries, 1, "byte budget bounds the run");
        assert!(r.exhausted);
        assert!(r.bytes >= one && r.bytes < 2 * one);
    }

    #[test]
    fn ram_overflow_spills_to_disk_oldest_first() {
        let pool = BlockPool::new();
        let dir = std::env::temp_dir().join(format!("fastav_tier_{}", std::process::id()));
        let _ = std::fs::create_dir_all(&dir);
        let path = dir.join("spill_oldest.tier");
        let one = SerializedEntry::from_entry(1, &[0], &entry(&pool, 4, 0.0)).payload_bytes();
        let store = TieredStore::new(TierConfig {
            ram_bytes: 2 * one + one / 2, // fits two entries
            disk_path: Some(path.clone()),
            disk_bytes: 0,
        });
        for i in 0..4u32 {
            store.stage_demotion(1, vec![i], Arc::new(entry(&pool, 4, i as f32)));
        }
        while store.prune_run(PruneBudget::default()).exhausted {}
        let s = store.stats();
        assert_eq!(s.ram_entries, 2, "RAM holds the newest two");
        assert_eq!(s.disk_entries, 2, "oldest two spilled to disk");
        // The oldest entries ([0], [1]) must now promote from disk.
        let (_, hit) = store.promote(&pool, 1, &[0]).expect("disk hit");
        assert_eq!(hit, TierHit::Disk);
        let (_, hit) = store.promote(&pool, 1, &[3]).expect("ram hit");
        assert_eq!(hit, TierHit::Ram);
        drop(store);
        assert!(!path.exists(), "backing file removed on drop");
    }

    #[test]
    fn disk_budget_drops_oldest_and_compaction_reclaims() {
        let pool = BlockPool::new();
        let dir = std::env::temp_dir().join(format!("fastav_tier_{}", std::process::id()));
        let _ = std::fs::create_dir_all(&dir);
        let path = dir.join("budget_drop.tier");
        let one = SerializedEntry::from_entry(1, &[0], &entry(&pool, 4, 0.0)).payload_bytes();
        let store = TieredStore::new(TierConfig {
            ram_bytes: 0, // straight to disk
            disk_path: Some(path.clone()),
            disk_bytes: 2 * one + one / 2,
        });
        for i in 0..5u32 {
            store.stage_demotion(1, vec![i], Arc::new(entry(&pool, 4, i as f32)));
        }
        while store.prune_run(PruneBudget::default()).exhausted {}
        let s = store.stats();
        assert_eq!(s.disk_entries, 2, "disk budget keeps the newest two");
        assert!(s.drops_disk >= 3, "oldest dropped under the disk budget");
        assert!(s.disk_bytes <= 2 * one + one / 2);
        // Dropped records leave dead file bytes; enough churn triggers
        // compaction and the file shrinks back to the live set.
        let before_file = s.disk_file_bytes;
        while store.prune_run(PruneBudget::default()).exhausted {}
        let after = store.stats();
        assert!(
            after.disk_file_bytes <= before_file,
            "compaction never grows the file"
        );
        // The survivors still decode cleanly after compaction.
        let (e, hit) = store.promote(&pool, 1, &[4]).expect("newest survives");
        assert_eq!(hit, TierHit::Disk);
        assert_eq!(e.prefix_len, 4);
    }

    #[test]
    fn flush_drains_all_tiers_and_resets_cursor() {
        let pool = BlockPool::new();
        let dir = std::env::temp_dir().join(format!("fastav_tier_{}", std::process::id()));
        let _ = std::fs::create_dir_all(&dir);
        let path = dir.join("flush_all.tier");
        let one = SerializedEntry::from_entry(1, &[0], &entry(&pool, 4, 0.0)).payload_bytes();
        let store = TieredStore::new(TierConfig {
            ram_bytes: one + one / 2, // fits one entry
            disk_path: Some(path.clone()),
            disk_bytes: 0,
        });
        for i in 0..3u32 {
            store.stage_demotion(1, vec![i], Arc::new(entry(&pool, 4, i as f32)));
        }
        // One tiny run leaves work in every stage: pending + a parked cursor.
        let r = store.prune_run(PruneBudget { max_entries: 1, max_bytes: usize::MAX });
        assert!(r.exhausted);
        let f = store.flush();
        assert!(f.pending_entries + f.ram_entries + f.disk_entries == 3);
        assert!(f.pending_bytes + f.ram_bytes + f.disk_bytes > 0);
        let s = store.stats();
        assert_eq!(
            (s.pending_entries, s.ram_entries, s.disk_entries, s.disk_file_bytes),
            (0, 0, 0, 0)
        );
        assert_eq!(s.cursor, PruneCursor::default(), "flush resets the pruner checkpoint");
        assert!(store.promote(&pool, 1, &[0]).is_none());
    }

    #[test]
    fn peek_sees_every_tier_without_promoting() {
        let pool = BlockPool::new();
        let store = TieredStore::new(TierConfig { ram_bytes: 1 << 20, ..Default::default() });
        store.stage_demotion(1, vec![1], Arc::new(entry(&pool, 4, 1.0)));
        assert!(store.peek(1, &[1]).is_some(), "pending visible");
        store.prune_run(PruneBudget::default());
        assert!(store.peek(1, &[1]).is_some(), "ram visible");
        assert_eq!(store.stats().ram_entries, 1, "peek must not promote");
        assert!(store.peek(1, &[2]).is_none());
        assert!(store.peek(2, &[1]).is_none(), "config keys isolate");
    }

    #[test]
    fn metrics_bound_series_track_operations() {
        let pool = BlockPool::new();
        let metrics = Registry::default();
        let store = TieredStore::new(TierConfig { ram_bytes: 1 << 20, ..Default::default() });
        store.bind_metrics(&metrics);
        store.stage_demotion(1, vec![1], Arc::new(entry(&pool, 4, 1.0)));
        store.prune_run(PruneBudget::default());
        store.promote(&pool, 1, &[1]).expect("ram promote");
        let text = metrics.export();
        assert!(text.contains("fastav_tier_demotions_total{tier=\"ram\"} 1"));
        assert!(text.contains("fastav_tier_promotions_total{tier=\"ram\"} 1"));
        assert!(text.contains("fastav_tier_bytes{tier=\"ram\"} 0"));
        assert!(text.contains("fastav_tier_promote_seconds_count 1"));
    }
}
