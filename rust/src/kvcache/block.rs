//! Fixed-size KV block pool: the allocation substrate of the paged cache.
//!
//! A *block* holds the K and V rows of up to [`BLOCK_TOKENS`] tokens for
//! one layer, stored token-major (`[BLOCK_TOKENS, n_heads * d_head]`), so
//! appending one token is a single contiguous row write. Blocks live in a
//! process-wide [`BlockPool`] and are *refcounted*: a [`super::LayerCache`]
//! owns references into the pool, cloning a cache bumps refcounts instead
//! of copying payloads, and the prefix cache shares one frozen AV-prefix
//! across every request that reuses it.
//!
//! Invariants (property-tested in `rust/tests/test_prefix.rs`):
//! * conservation — every slot is either on the free list or referenced
//!   (`used + free == slots`), and a released block reaches refcount 0
//!   exactly once;
//! * copy-on-write — a block with refcount > 1 is never written through
//!   (`write_row` asserts sole ownership); writers fork first via
//!   [`BlockPool::fork`];
//! * clean padding — freshly allocated (and recycled) blocks are
//!   zero-filled, so slots beyond a cache's live length always read 0.0.

use std::sync::{Arc, Mutex, OnceLock};

/// Tokens per block. Small enough that a forked tail block copies little,
/// large enough that block lists stay short for bucket-sized caches.
pub const BLOCK_TOKENS: usize = 16;

/// Pool-internal block storage.
struct BlockSlot {
    /// Outstanding references; 0 means the slot is on the free list.
    refs: u32,
    /// `n_heads * d_head` — the per-token row width this slot is sized for.
    row_elems: usize,
    k: Vec<f32>,
    v: Vec<f32>,
}

#[derive(Default)]
struct PoolInner {
    slots: Vec<BlockSlot>,
    free: Vec<usize>,
}

/// Point-in-time pool accounting (the `kv_blocks_*` gauges).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct BlockPoolStats {
    /// Slots with refcount >= 1.
    pub used: usize,
    /// Slots with refcount >= 2 (shared between caches / prefix entries).
    pub shared: usize,
    /// Recycled slots awaiting reuse.
    pub free: usize,
    /// K+V payload bytes of used slots, each block counted once no matter
    /// how many caches reference it.
    pub bytes_used: usize,
}

/// A shared, refcounted pool of fixed-size KV blocks. Cheap to clone
/// (`Arc` handle); all methods take `&self` and lock internally, so one
/// pool can back caches on every replica thread.
#[derive(Clone)]
pub struct BlockPool {
    inner: Arc<Mutex<PoolInner>>,
}

impl Default for BlockPool {
    fn default() -> Self {
        BlockPool::new()
    }
}

impl std::fmt::Debug for BlockPool {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let s = self.stats();
        write!(f, "BlockPool(used={}, shared={}, free={})", s.used, s.shared, s.free)
    }
}

impl BlockPool {
    /// A fresh, isolated pool (tests; the serving stack uses
    /// [`BlockPool::global`]).
    pub fn new() -> BlockPool {
        BlockPool { inner: Arc::new(Mutex::new(PoolInner::default())) }
    }

    /// The process-wide pool every [`super::LayerCache`] built without an
    /// explicit pool allocates from. One pool per process is what lets
    /// prefix entries created on one replica back caches on another.
    pub fn global() -> BlockPool {
        static GLOBAL: OnceLock<BlockPool> = OnceLock::new();
        GLOBAL.get_or_init(BlockPool::new).clone()
    }

    /// Whether two handles refer to the same underlying pool.
    pub fn same_pool(&self, other: &BlockPool) -> bool {
        Arc::ptr_eq(&self.inner, &other.inner)
    }

    /// Allocate a zero-filled block sized for `row_elems`-wide token rows,
    /// returning its id with refcount 1.
    pub fn alloc(&self, row_elems: usize) -> usize {
        assert!(row_elems > 0, "zero-width block row");
        let mut p = self.inner.lock().unwrap();
        // Reuse a free slot of the same geometry if one exists.
        if let Some(pos) = p
            .free
            .iter()
            .position(|&id| p.slots[id].row_elems == row_elems)
        {
            let id = p.free.swap_remove(pos);
            let s = &mut p.slots[id];
            s.k.fill(0.0);
            s.v.fill(0.0);
            s.refs = 1;
            return id;
        }
        let elems = BLOCK_TOKENS * row_elems;
        p.slots.push(BlockSlot {
            refs: 1,
            row_elems,
            k: vec![0.0; elems],
            v: vec![0.0; elems],
        });
        p.slots.len() - 1
    }

    /// Add a reference (cache clone / prefix share).
    pub fn retain(&self, id: usize) {
        let mut p = self.inner.lock().unwrap();
        let s = &mut p.slots[id];
        assert!(s.refs > 0, "retain of a free block {}", id);
        s.refs += 1;
    }

    /// Drop a reference; the block is recycled when the count hits 0.
    pub fn release(&self, id: usize) {
        let mut p = self.inner.lock().unwrap();
        let s = &mut p.slots[id];
        assert!(s.refs > 0, "release of a free block {}", id);
        s.refs -= 1;
        if s.refs == 0 {
            p.free.push(id);
        }
    }

    /// Current refcount (COW decision point).
    pub fn refs(&self, id: usize) -> u32 {
        self.inner.lock().unwrap().slots[id].refs
    }

    /// Copy-on-write fork: a new block (refcount 1) with the same payload.
    /// The caller keeps its reference on `id` and must release it
    /// separately if it is swapping the fork in.
    pub fn fork(&self, id: usize) -> usize {
        let row_elems = {
            let p = self.inner.lock().unwrap();
            p.slots[id].row_elems
        };
        let copy = self.alloc(row_elems);
        let mut p = self.inner.lock().unwrap();
        // Split the slots vector to borrow source and destination at once.
        let (src, dst) = if id < copy {
            let (a, b) = p.slots.split_at_mut(copy);
            (&a[id], &mut b[0])
        } else {
            let (a, b) = p.slots.split_at_mut(id);
            (&b[0], &mut a[copy])
        };
        dst.k.copy_from_slice(&src.k);
        dst.v.copy_from_slice(&src.v);
        copy
    }

    /// Write one token's K/V row (`row_elems` floats each) at `slot`.
    /// COW safety: asserts the block is solely owned.
    pub fn write_row(&self, id: usize, slot: usize, k_row: &[f32], v_row: &[f32]) {
        assert!(slot < BLOCK_TOKENS);
        let mut p = self.inner.lock().unwrap();
        let s = &mut p.slots[id];
        assert_eq!(s.refs, 1, "copy-on-write violation: write to shared block {}", id);
        let w = s.row_elems;
        assert_eq!(k_row.len(), w);
        assert_eq!(v_row.len(), w);
        s.k[slot * w..(slot + 1) * w].copy_from_slice(k_row);
        s.v[slot * w..(slot + 1) * w].copy_from_slice(v_row);
    }

    /// Zero every row at or beyond `from_slot` (the in-place compact
    /// fast path restores the clean-padding invariant with this).
    /// COW safety: asserts the block is solely owned, like `write_row`.
    pub fn zero_tail(&self, id: usize, from_slot: usize) {
        assert!(from_slot <= BLOCK_TOKENS);
        let mut p = self.inner.lock().unwrap();
        let s = &mut p.slots[id];
        assert_eq!(s.refs, 1, "copy-on-write violation: zero of shared block {}", id);
        let w = s.row_elems;
        s.k[from_slot * w..].fill(0.0);
        s.v[from_slot * w..].fill(0.0);
    }

    /// Read access to a block's K/V payload under the pool lock.
    pub fn with_kv<R>(&self, id: usize, f: impl FnOnce(&[f32], &[f32]) -> R) -> R {
        let p = self.inner.lock().unwrap();
        let s = &p.slots[id];
        assert!(s.refs > 0, "read of a free block {}", id);
        f(&s.k, &s.v)
    }

    /// Pool-wide accounting snapshot.
    pub fn stats(&self) -> BlockPoolStats {
        let p = self.inner.lock().unwrap();
        let mut st = BlockPoolStats::default();
        for s in &p.slots {
            if s.refs > 0 {
                st.used += 1;
                st.bytes_used += (s.k.len() + s.v.len()) * std::mem::size_of::<f32>();
                if s.refs > 1 {
                    st.shared += 1;
                }
            }
        }
        st.free = p.free.len();
        debug_assert_eq!(st.used + st.free, p.slots.len(), "pool conservation");
        st
    }

    /// Total slots ever created (conservation checks in tests).
    pub fn total_slots(&self) -> usize {
        self.inner.lock().unwrap().slots.len()
    }
}

/// Payload bytes of one block sized for `row_elems`-wide rows (K + V).
pub fn block_bytes(row_elems: usize) -> usize {
    2 * BLOCK_TOKENS * row_elems * std::mem::size_of::<f32>()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn alloc_release_recycles() {
        let p = BlockPool::new();
        let a = p.alloc(8);
        assert_eq!(p.refs(a), 1);
        p.release(a);
        assert_eq!(p.stats().free, 1);
        let b = p.alloc(8);
        assert_eq!(b, a, "same-geometry slot is recycled");
        assert_eq!(p.total_slots(), 1);
        p.release(b);
    }

    #[test]
    fn recycled_blocks_are_zeroed() {
        let p = BlockPool::new();
        let a = p.alloc(2);
        p.write_row(a, 3, &[1.0, 2.0], &[3.0, 4.0]);
        p.release(a);
        let b = p.alloc(2);
        p.with_kv(b, |k, v| {
            assert!(k.iter().all(|&x| x == 0.0));
            assert!(v.iter().all(|&x| x == 0.0));
        });
        p.release(b);
    }

    #[test]
    fn geometry_mismatch_allocates_new_slot() {
        let p = BlockPool::new();
        let a = p.alloc(4);
        p.release(a);
        let b = p.alloc(8); // different row width: must not reuse slot a
        assert_ne!(a, b);
        p.release(b);
    }

    #[test]
    fn fork_copies_payload_and_is_sole_owned() {
        let p = BlockPool::new();
        let a = p.alloc(2);
        p.write_row(a, 0, &[5.0, 6.0], &[7.0, 8.0]);
        p.retain(a); // now shared
        let f = p.fork(a);
        assert_eq!(p.refs(f), 1);
        p.with_kv(f, |k, _| assert_eq!(&k[..2], &[5.0, 6.0]));
        // Writing the fork must not touch the original.
        p.write_row(f, 0, &[9.0, 9.0], &[9.0, 9.0]);
        p.with_kv(a, |k, _| assert_eq!(&k[..2], &[5.0, 6.0]));
        p.release(a);
        p.release(a);
        p.release(f);
        assert_eq!(p.stats().used, 0);
    }

    #[test]
    #[should_panic(expected = "copy-on-write violation")]
    fn write_to_shared_block_panics() {
        let p = BlockPool::new();
        let a = p.alloc(2);
        p.retain(a);
        p.write_row(a, 0, &[0.0, 0.0], &[0.0, 0.0]);
    }

    #[test]
    fn stats_track_shared() {
        let p = BlockPool::new();
        let a = p.alloc(2);
        let b = p.alloc(2);
        p.retain(a);
        let s = p.stats();
        assert_eq!(s.used, 2);
        assert_eq!(s.shared, 1);
        assert_eq!(s.bytes_used, 2 * block_bytes(2));
        p.release(a);
        p.release(a);
        p.release(b);
        assert_eq!(p.stats().used, 0);
        assert_eq!(p.stats().free, 2);
    }
}
