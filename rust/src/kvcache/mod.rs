//! Per-layer compacted KV caches.
//!
//! FastAV's fine pruning gives every layer a *different* live token set,
//! so each layer owns an independent cache. Layout matches the artifact
//! ABI exactly — `[H, cap, dh]` row-major f32, where `cap` is the compiled
//! bucket capacity — so cache slices upload to PJRT without reshuffling.
//!
//! Invariants (property-tested in `rust/tests/`):
//! * slots `0..len` are live, `len..cap` are padding;
//! * `positions[i]` is the token's *original* sequence position (RoPE
//!   phases survive compaction);
//! * `compact(keep)` preserves (position → K/V row) for kept tokens;
//! * `grow(cap')` preserves all live rows and their order.

/// KV cache for one transformer layer.
#[derive(Debug, Clone)]
pub struct LayerCache {
    pub n_heads: usize,
    pub d_head: usize,
    cap: usize,
    len: usize,
    k: Vec<f32>,
    v: Vec<f32>,
    positions: Vec<i32>,
}

impl LayerCache {
    /// Empty cache with `cap` slots.
    pub fn new(n_heads: usize, d_head: usize, cap: usize) -> LayerCache {
        LayerCache {
            n_heads,
            d_head,
            cap,
            len: 0,
            k: vec![0.0; n_heads * cap * d_head],
            v: vec![0.0; n_heads * cap * d_head],
            positions: Vec::with_capacity(cap),
        }
    }

    /// Build from prefill output `[H, src_n, dh]` keeping rows `0..valid`.
    /// `positions[i]` gives the original position of row `i`.
    pub fn from_prefill(
        n_heads: usize,
        d_head: usize,
        cap: usize,
        src_k: &[f32],
        src_v: &[f32],
        src_n: usize,
        valid: usize,
        positions: &[i32],
    ) -> LayerCache {
        assert!(valid <= cap && valid <= src_n);
        assert_eq!(src_k.len(), n_heads * src_n * d_head);
        assert_eq!(positions.len(), valid);
        let mut c = LayerCache::new(n_heads, d_head, cap);
        for h in 0..n_heads {
            let src_base = h * src_n * d_head;
            let dst_base = h * cap * d_head;
            let rows = valid * d_head;
            c.k[dst_base..dst_base + rows]
                .copy_from_slice(&src_k[src_base..src_base + rows]);
            c.v[dst_base..dst_base + rows]
                .copy_from_slice(&src_v[src_base..src_base + rows]);
        }
        c.len = valid;
        c.positions.extend_from_slice(positions);
        c
    }

    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    pub fn cap(&self) -> usize {
        self.cap
    }

    pub fn positions(&self) -> &[i32] {
        &self.positions
    }

    pub fn k_data(&self) -> &[f32] {
        &self.k
    }

    pub fn v_data(&self) -> &[f32] {
        &self.v
    }

    /// Heap bytes of the K/V payload (the paper's memory metric).
    pub fn bytes(&self) -> usize {
        (self.k.len() + self.v.len()) * std::mem::size_of::<f32>()
    }

    /// Byte footprint of one layer's K+V slab at capacity `cap`, without
    /// building it — serving admission gates on this estimate before a
    /// request is allowed to allocate real caches.
    pub fn slab_bytes(n_heads: usize, d_head: usize, cap: usize) -> usize {
        2 * n_heads * cap * d_head * std::mem::size_of::<f32>()
    }

    /// Validity mask over the `cap` slots (1.0 for live rows).
    pub fn mask(&self) -> Vec<f32> {
        let mut m = vec![0.0f32; self.cap];
        for slot in m.iter_mut().take(self.len) {
            *slot = 1.0;
        }
        m
    }

    /// One K row (head `h`, slot `i`) — test/debug helper.
    pub fn k_row(&self, h: usize, i: usize) -> &[f32] {
        let base = h * self.cap * self.d_head + i * self.d_head;
        &self.k[base..base + self.d_head]
    }

    pub fn v_row(&self, h: usize, i: usize) -> &[f32] {
        let base = h * self.cap * self.d_head + i * self.d_head;
        &self.v[base..base + self.d_head]
    }

    /// Keep only the slots in `keep` (ascending, unique, all `< len`),
    /// compacting rows to the front. Positions follow their rows.
    pub fn compact(&mut self, keep: &[usize]) {
        debug_assert!(keep.windows(2).all(|w| w[0] < w[1]), "keep must be ascending");
        if let Some(&last) = keep.last() {
            assert!(last < self.len, "keep index {} out of range {}", last, self.len);
        }
        let dh = self.d_head;
        for h in 0..self.n_heads {
            let base = h * self.cap * dh;
            for (dst, &src) in keep.iter().enumerate() {
                if dst == src {
                    continue; // prefix already in place
                }
                self.k.copy_within(base + src * dh..base + (src + 1) * dh, base + dst * dh);
                self.v.copy_within(base + src * dh..base + (src + 1) * dh, base + dst * dh);
            }
        }
        let new_pos: Vec<i32> = keep.iter().map(|&i| self.positions[i]).collect();
        self.positions = new_pos;
        self.len = keep.len();
        // Zero the now-dead tail so masked kernels see clean padding.
        for h in 0..self.n_heads {
            let base = h * self.cap * dh;
            for i in self.len..self.cap.min(self.len + 64) {
                self.k[base + i * dh..base + (i + 1) * dh].fill(0.0);
                self.v[base + i * dh..base + (i + 1) * dh].fill(0.0);
            }
        }
    }

    /// Append one token's K/V (`[H, dh]` each) at original position `pos`.
    /// The caller must ensure capacity (`grow` first if needed).
    pub fn append(&mut self, k_new: &[f32], v_new: &[f32], pos: i32) {
        assert!(self.len < self.cap, "cache full: len={} cap={}", self.len, self.cap);
        assert_eq!(k_new.len(), self.n_heads * self.d_head);
        let dh = self.d_head;
        for h in 0..self.n_heads {
            let dst = h * self.cap * dh + self.len * dh;
            self.k[dst..dst + dh].copy_from_slice(&k_new[h * dh..(h + 1) * dh]);
            self.v[dst..dst + dh].copy_from_slice(&v_new[h * dh..(h + 1) * dh]);
        }
        self.positions.push(pos);
        self.len += 1;
    }

    /// Re-layout into a larger capacity (next bucket).
    pub fn grow(&mut self, new_cap: usize) {
        assert!(new_cap >= self.len);
        if new_cap == self.cap {
            return;
        }
        let dh = self.d_head;
        let mut k = vec![0.0f32; self.n_heads * new_cap * dh];
        let mut v = vec![0.0f32; self.n_heads * new_cap * dh];
        for h in 0..self.n_heads {
            let src = h * self.cap * dh;
            let dst = h * new_cap * dh;
            let rows = self.len * dh;
            k[dst..dst + rows].copy_from_slice(&self.k[src..src + rows]);
            v[dst..dst + rows].copy_from_slice(&self.v[src..src + rows]);
        }
        self.k = k;
        self.v = v;
        self.cap = new_cap;
    }
}

/// All layers' caches for one request + peak-memory accounting.
#[derive(Debug, Clone, Default)]
pub struct CacheSet {
    pub layers: Vec<LayerCache>,
    peak_bytes: usize,
}

impl CacheSet {
    pub fn push(&mut self, c: LayerCache) {
        self.layers.push(c);
        self.update_peak();
    }

    pub fn bytes(&self) -> usize {
        self.layers.iter().map(|c| c.bytes()).sum()
    }

    pub fn peak_bytes(&self) -> usize {
        self.peak_bytes
    }

    pub fn update_peak(&mut self) {
        self.peak_bytes = self.peak_bytes.max(self.bytes());
    }

    /// Live token count per layer (the pruning trace).
    pub fn live_counts(&self) -> Vec<usize> {
        self.layers.iter().map(|c| c.len()).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn filled(n_heads: usize, dh: usize, cap: usize, n: usize) -> LayerCache {
        // K row value = 100*h + i, V = negative of that; positions = 10+i.
        let mut k = vec![0.0f32; n_heads * n * dh];
        let mut v = vec![0.0f32; n_heads * n * dh];
        for h in 0..n_heads {
            for i in 0..n {
                for d in 0..dh {
                    k[h * n * dh + i * dh + d] = (100 * h + i) as f32;
                    v[h * n * dh + i * dh + d] = -((100 * h + i) as f32);
                }
            }
        }
        let positions: Vec<i32> = (0..n as i32).map(|i| 10 + i).collect();
        LayerCache::from_prefill(n_heads, dh, cap, &k, &v, n, n, &positions)
    }

    #[test]
    fn from_prefill_copies_rows() {
        let c = filled(2, 4, 8, 5);
        assert_eq!(c.len(), 5);
        assert_eq!(c.k_row(1, 3)[0], 103.0);
        assert_eq!(c.v_row(0, 2)[0], -2.0);
        assert_eq!(c.positions(), &[10, 11, 12, 13, 14]);
    }

    #[test]
    fn compact_preserves_position_row_mapping() {
        let mut c = filled(2, 4, 8, 6);
        c.compact(&[0, 2, 5]);
        assert_eq!(c.len(), 3);
        assert_eq!(c.positions(), &[10, 12, 15]);
        assert_eq!(c.k_row(0, 0)[0], 0.0);
        assert_eq!(c.k_row(0, 1)[0], 2.0);
        assert_eq!(c.k_row(0, 2)[0], 5.0);
        assert_eq!(c.k_row(1, 2)[0], 105.0);
        // mask reflects new length
        let m = c.mask();
        assert_eq!(m.iter().filter(|&&x| x > 0.5).count(), 3);
    }

    #[test]
    fn append_then_read_back() {
        let mut c = filled(2, 4, 8, 3);
        let k_new = vec![7.0f32; 8];
        let v_new = vec![-7.0f32; 8];
        c.append(&k_new, &v_new, 42);
        assert_eq!(c.len(), 4);
        assert_eq!(c.k_row(0, 3)[0], 7.0);
        assert_eq!(c.k_row(1, 3)[0], 7.0);
        assert_eq!(c.positions().last(), Some(&42));
    }

    #[test]
    fn grow_preserves_rows() {
        let mut c = filled(2, 4, 8, 6);
        c.compact(&[1, 4]);
        c.grow(16);
        assert_eq!(c.cap(), 16);
        assert_eq!(c.len(), 2);
        assert_eq!(c.k_row(0, 0)[0], 1.0);
        assert_eq!(c.k_row(1, 1)[0], 104.0);
        assert_eq!(c.positions(), &[11, 14]);
    }

    #[test]
    #[should_panic(expected = "cache full")]
    fn append_past_capacity_panics() {
        let mut c = filled(1, 2, 3, 3);
        c.append(&[0.0, 0.0], &[0.0, 0.0], 1);
    }

    #[test]
    fn bytes_accounting() {
        let c = LayerCache::new(2, 4, 8);
        assert_eq!(c.bytes(), 2 * 2 * 8 * 4 * 4); // k+v, H, cap, dh, f32
        assert_eq!(LayerCache::slab_bytes(2, 4, 8), c.bytes());
        let mut set = CacheSet::default();
        set.push(c);
        assert_eq!(set.bytes(), set.peak_bytes());
        assert_eq!(set.live_counts(), vec![0]);
    }

    #[test]
    fn peak_tracks_maximum() {
        let mut set = CacheSet::default();
        set.push(LayerCache::new(1, 2, 16));
        let before = set.peak_bytes();
        set.layers[0].grow(32);
        set.update_peak();
        assert!(set.peak_bytes() > before);
    }
}
