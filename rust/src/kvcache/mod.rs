//! Paged per-layer KV caches over a refcounted block pool.
//!
//! FastAV's fine pruning gives every layer a *different* live token set,
//! so each layer owns an independent cache. Storage is **paged**: a
//! [`LayerCache`] is a view over a list of fixed-size blocks
//! ([`block::BLOCK_TOKENS`] token rows each) owned by a shared, refcounted
//! [`BlockPool`]. Capacity is logical — `grow` re-targets the compiled
//! bucket without moving a byte — and cloning a cache bumps block
//! refcounts instead of copying payloads, which is what makes the
//! [`prefix`] cache's AV-prefix sharing O(1) per request.
//!
//! Copy-on-write: `append` and `compact` fork only the blocks they
//! rewrite. A frozen prefix shared with the [`prefix::PrefixCache`] (or
//! with another request) is never copied — fine pruning on one request
//! cannot perturb another request sharing its prefix (property-tested in
//! `rust/tests/test_prefix.rs`).
//!
//! Invariants (property-tested in `rust/tests/`):
//! * slots `0..len` are live; every allocated slot `>= len` reads 0.0
//!   (blocks are zero-filled on allocation and recycled zeroed, and
//!   `compact` rebuilds its tail into fresh blocks — the vacated range is
//!   exactly zero, not just the first 64 rows as in the pre-paged layout);
//! * `positions[i]` is the token's *original* sequence position (RoPE
//!   phases survive compaction);
//! * `compact(keep)` preserves (position → K/V row) for kept tokens and
//!   never writes through a block with refcount > 1;
//! * `grow(cap')` preserves all live rows and their order;
//! * upload layout is materialized on demand by [`LayerCache::padded_kv`]
//!   as `[H, cap, dh]` row-major f32 — the artifact ABI is unchanged.

pub mod block;
pub mod gather;
pub mod prefix;
pub mod tier;

pub use block::{block_bytes, BlockPool, BlockPoolStats, BLOCK_TOKENS};
pub use gather::{GatherBuf, GatherStats};
pub use prefix::{
    PerConfigPrefixStats, PrefixCache, PrefixCacheStats, PrefixEntry, PrefixLease,
};
pub use tier::{
    PruneBudget, PruneCursor, PruneRunReport, SerializedEntry, TierConfig, TierFlush,
    TierHit, TierStats, TieredStore,
};

use std::sync::atomic::{AtomicU64, Ordering};

/// Process-wide cache-identity counter for delta-upload validity
/// tracking (see [`LayerCache::id`]). Never reused; a u64 cannot wrap
/// in practice.
static NEXT_CACHE_ID: AtomicU64 = AtomicU64::new(1);

fn next_cache_id() -> u64 {
    NEXT_CACHE_ID.fetch_add(1, Ordering::Relaxed)
}

/// KV cache for one transformer layer: a refcounted block list plus the
/// live length, logical capacity, and original token positions.
#[derive(Debug)]
pub struct LayerCache {
    pub n_heads: usize,
    pub d_head: usize,
    cap: usize,
    len: usize,
    blocks: Vec<usize>,
    positions: Vec<i32>,
    pool: BlockPool,
    /// Unique identity for upload-buffer validity tracking. Fresh on
    /// construction *and on clone*: a clone shares blocks but can
    /// diverge through copy-on-write, so it must never pass for the
    /// cache a [`GatherBuf`] row was gathered from.
    id: u64,
    /// Bumped whenever existing rows move or change ([`Self::compact`]).
    /// `append` and `grow` preserve the live prefix rows byte-for-byte
    /// and do NOT bump — that is exactly what makes delta-append uploads
    /// (copy only rows past the previous fill) valid.
    epoch: u64,
}

impl Clone for LayerCache {
    /// O(blocks) refcount bumps; payloads are shared until a writer forks.
    fn clone(&self) -> LayerCache {
        for &id in &self.blocks {
            self.pool.retain(id);
        }
        LayerCache {
            n_heads: self.n_heads,
            d_head: self.d_head,
            cap: self.cap,
            len: self.len,
            blocks: self.blocks.clone(),
            positions: self.positions.clone(),
            pool: self.pool.clone(),
            id: next_cache_id(),
            epoch: self.epoch,
        }
    }
}

impl Drop for LayerCache {
    fn drop(&mut self) {
        for &id in &self.blocks {
            self.pool.release(id);
        }
    }
}

impl LayerCache {
    /// Empty cache with logical capacity `cap`, allocating from the
    /// process-wide [`BlockPool::global`]. No blocks are allocated until
    /// rows are appended.
    pub fn new(n_heads: usize, d_head: usize, cap: usize) -> LayerCache {
        Self::new_in(BlockPool::global(), n_heads, d_head, cap)
    }

    /// [`LayerCache::new`] against an explicit pool (isolated tests).
    pub fn new_in(pool: BlockPool, n_heads: usize, d_head: usize, cap: usize) -> LayerCache {
        LayerCache {
            n_heads,
            d_head,
            cap,
            len: 0,
            blocks: Vec::new(),
            positions: Vec::with_capacity(cap.min(1024)),
            pool,
            id: next_cache_id(),
            epoch: 0,
        }
    }

    /// Build from prefill output `[H, src_n, dh]` keeping rows `0..valid`.
    /// `positions[i]` gives the original position of row `i`.
    #[allow(clippy::too_many_arguments)]
    pub fn from_prefill(
        n_heads: usize,
        d_head: usize,
        cap: usize,
        src_k: &[f32],
        src_v: &[f32],
        src_n: usize,
        valid: usize,
        positions: &[i32],
    ) -> LayerCache {
        assert!(valid <= cap && valid <= src_n);
        assert_eq!(src_k.len(), n_heads * src_n * d_head);
        assert_eq!(positions.len(), valid);
        let mut c = LayerCache::new(n_heads, d_head, cap);
        let dh = d_head;
        let mut k_row = vec![0.0f32; n_heads * dh];
        let mut v_row = vec![0.0f32; n_heads * dh];
        for (i, &pos) in positions.iter().enumerate().take(valid) {
            for h in 0..n_heads {
                let src = h * src_n * dh + i * dh;
                k_row[h * dh..(h + 1) * dh].copy_from_slice(&src_k[src..src + dh]);
                v_row[h * dh..(h + 1) * dh].copy_from_slice(&src_v[src..src + dh]);
            }
            c.append(&k_row, &v_row, pos);
        }
        c
    }

    /// Gather `rows` of a `[H, src_n, dh]` K/V slab pair into a fresh
    /// paged cache allocated from `pool`; row `i` keeps `rows[i]` as its
    /// original position. This is the one strided row-gather under every
    /// prefill-output → cache build (engine front caches, prefix-cache
    /// entry construction, and the per-shard mesh builds).
    #[allow(clippy::too_many_arguments)]
    pub fn from_strided_rows(
        pool: BlockPool,
        n_heads: usize,
        d_head: usize,
        cap: usize,
        src_k: &[f32],
        src_v: &[f32],
        src_n: usize,
        rows: &[usize],
    ) -> LayerCache {
        assert!(rows.len() <= cap);
        assert_eq!(src_k.len(), n_heads * src_n * d_head);
        assert_eq!(src_v.len(), n_heads * src_n * d_head);
        let dh = d_head;
        let mut c = LayerCache::new_in(pool, n_heads, d_head, cap);
        let mut k_row = vec![0.0f32; n_heads * dh];
        let mut v_row = vec![0.0f32; n_heads * dh];
        for &orig in rows {
            debug_assert!(orig < src_n);
            for h in 0..n_heads {
                let base = h * src_n * dh + orig * dh;
                k_row[h * dh..(h + 1) * dh].copy_from_slice(&src_k[base..base + dh]);
                v_row[h * dh..(h + 1) * dh].copy_from_slice(&src_v[base..base + dh]);
            }
            c.append(&k_row, &v_row, orig as i32);
        }
        c
    }

    fn row_elems(&self) -> usize {
        self.n_heads * self.d_head
    }

    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    pub fn cap(&self) -> usize {
        self.cap
    }

    pub fn positions(&self) -> &[i32] {
        &self.positions
    }

    /// Unique cache identity: never shared between two live caches
    /// (cloning mints a new one). Together with [`Self::epoch`] and the
    /// live length, this is the validity tuple a [`GatherBuf`] row
    /// stores to decide whether a delta-append copy (new tail rows
    /// only) can replace a full re-gather.
    pub fn id(&self) -> u64 {
        self.id
    }

    /// Row-stability epoch: bumped by [`Self::compact`] (rows move),
    /// preserved by `append`/`grow` (the live prefix is untouched).
    pub fn epoch(&self) -> u64 {
        self.epoch
    }

    /// The pool this cache allocates from.
    pub fn pool(&self) -> &BlockPool {
        &self.pool
    }

    /// Block ids backing this cache (refcount inspection in tests).
    pub fn block_ids(&self) -> &[usize] {
        &self.blocks
    }

    /// Heap bytes of the K/V payload actually allocated (paged: blocks ×
    /// block size, independent of the logical `cap`). A block shared with
    /// another cache is counted here by *each* holder; pool-level
    /// accounting that counts shared blocks once lives in
    /// [`BlockPool::stats`].
    pub fn bytes(&self) -> usize {
        self.blocks.len() * block_bytes(self.row_elems())
    }

    /// Byte footprint of one layer's K+V at capacity `cap`, without
    /// building it — serving admission gates on this *upper bound* before
    /// a request is allowed to allocate real blocks (paged allocation can
    /// only come in under it).
    pub fn slab_bytes(n_heads: usize, d_head: usize, cap: usize) -> usize {
        2 * n_heads * cap * d_head * std::mem::size_of::<f32>()
    }

    /// Validity mask over the `cap` slots (1.0 for live rows).
    pub fn mask(&self) -> Vec<f32> {
        let mut m = vec![0.0f32; self.cap];
        for slot in m.iter_mut().take(self.len) {
            *slot = 1.0;
        }
        m
    }

    /// One K row (head `h`, slot `i`) — test/debug helper. Slots beyond
    /// the allocated blocks read as padding (all zero).
    pub fn k_row(&self, h: usize, i: usize) -> Vec<f32> {
        self.read_row(h, i, false)
    }

    pub fn v_row(&self, h: usize, i: usize) -> Vec<f32> {
        self.read_row(h, i, true)
    }

    fn read_row(&self, h: usize, i: usize, want_v: bool) -> Vec<f32> {
        assert!(i < self.cap, "slot {} out of cap {}", i, self.cap);
        let dh = self.d_head;
        let w = self.row_elems();
        let bi = i / BLOCK_TOKENS;
        if bi >= self.blocks.len() {
            return vec![0.0; dh]; // unallocated padding
        }
        let slot = i % BLOCK_TOKENS;
        self.pool.with_kv(self.blocks[bi], |k, v| {
            let src = if want_v { v } else { k };
            src[slot * w + h * dh..slot * w + (h + 1) * dh].to_vec()
        })
    }

    /// Materialize the artifact-ABI upload layout: `[H, cap, dh]` K and V
    /// slabs, zero-padded beyond `len`.
    pub fn padded_kv(&self) -> (Vec<f32>, Vec<f32>) {
        let mut k_out = Vec::new();
        let mut v_out = Vec::new();
        self.padded_kv_into(&mut k_out, &mut v_out);
        (k_out, v_out)
    }

    /// [`Self::padded_kv`] into caller-owned buffers — the decode hot
    /// path reuses scratch buffers so the per-step gather allocates
    /// nothing. The buffers are grown as needed but **never shrunk**
    /// (high-water sizing): only the first `n_heads * cap * d_head`
    /// elements are written; callers slice.
    pub fn padded_kv_into(&self, k_out: &mut Vec<f32>, v_out: &mut Vec<f32>) {
        let elems = self.n_heads * self.cap * self.d_head;
        if k_out.len() < elems {
            k_out.resize(elems, 0.0);
        }
        if v_out.len() < elems {
            v_out.resize(elems, 0.0);
        }
        self.padded_kv_fill(self.cap, &mut k_out[..elems], &mut v_out[..elems]);
    }

    /// Materialize the upload layout at an explicit capacity `cap >= len`
    /// into exactly-sized slices (`[H, cap, dh]` each, zeroed here first).
    /// This is the shared gather under [`Self::padded_kv_into`] and the
    /// batched [`Self::padded_kv_batch_into`]: a batch of requests is
    /// written at one *joint* capacity regardless of each cache's own
    /// logical `cap`.
    pub fn padded_kv_fill(&self, cap: usize, k_out: &mut [f32], v_out: &mut [f32]) {
        self.padded_kv_fill_ext(cap, k_out, v_out, cap);
    }

    /// [`Self::padded_kv_fill`] with an explicit previous fill extent:
    /// only slots `len..min(prev_rows, cap)` are zeroed (everything the
    /// last occupant of these slices could have written), and slots
    /// beyond `prev_rows` are trusted to already read zero. With
    /// `prev_rows == cap` this is exactly the stateless fill; with the
    /// extent tracked per buffer row (see [`GatherBuf`]) it skips the
    /// redundant re-zero of never-occupied padding that the old
    /// full-buffer `fill(0.0)` paid on every call.
    pub fn padded_kv_fill_ext(
        &self,
        cap: usize,
        k_out: &mut [f32],
        v_out: &mut [f32],
        prev_rows: usize,
    ) {
        let (h_n, dh, w) = (self.n_heads, self.d_head, self.row_elems());
        assert!(cap >= self.len, "fill cap {} below live length {}", cap, self.len);
        assert_eq!(k_out.len(), h_n * cap * dh);
        assert_eq!(v_out.len(), h_n * cap * dh);
        // Zero only the potentially-stale padding band: live rows are
        // fully overwritten by the copy below, and rows past prev_rows
        // were never written by the previous occupant.
        let stale_to = prev_rows.min(cap);
        if stale_to > self.len {
            for h in 0..h_n {
                let base = h * cap * dh;
                k_out[base + self.len * dh..base + stale_to * dh].fill(0.0);
                v_out[base + self.len * dh..base + stale_to * dh].fill(0.0);
            }
        }
        for (bi, &id) in self.blocks.iter().enumerate() {
            let base_tok = bi * BLOCK_TOKENS;
            let rows = BLOCK_TOKENS.min(self.len.saturating_sub(base_tok));
            if rows == 0 {
                break;
            }
            self.pool.with_kv(id, |k, v| {
                for s in 0..rows {
                    let tok = base_tok + s;
                    for h in 0..h_n {
                        let src = s * w + h * dh;
                        let dst = h * cap * dh + tok * dh;
                        k_out[dst..dst + dh].copy_from_slice(&k[src..src + dh]);
                        v_out[dst..dst + dh].copy_from_slice(&v[src..src + dh]);
                    }
                }
            });
        }
    }

    /// Delta-append copy: write only rows `from..len` into an upload
    /// slice pair that already holds this cache's rows `0..from` (and
    /// zero padding) at the same `cap` — the per-step decode case where
    /// the block list is unchanged except newly appended rows. The
    /// caller proves validity with the ([`Self::id`], [`Self::epoch`])
    /// tuple; [`GatherBuf::fill`] is the checked entry point.
    pub fn padded_kv_fill_tail(
        &self,
        cap: usize,
        from: usize,
        k_out: &mut [f32],
        v_out: &mut [f32],
    ) {
        let (h_n, dh, w) = (self.n_heads, self.d_head, self.row_elems());
        assert!(from <= self.len, "tail from {} past live length {}", from, self.len);
        assert!(cap >= self.len, "fill cap {} below live length {}", cap, self.len);
        assert_eq!(k_out.len(), h_n * cap * dh);
        assert_eq!(v_out.len(), h_n * cap * dh);
        for (bi, &id) in self.blocks.iter().enumerate() {
            let base_tok = bi * BLOCK_TOKENS;
            let rows = BLOCK_TOKENS.min(self.len.saturating_sub(base_tok));
            if rows == 0 {
                break;
            }
            if base_tok + rows <= from {
                continue; // block entirely within the already-uploaded prefix
            }
            let start = from.saturating_sub(base_tok);
            self.pool.with_kv(id, |k, v| {
                for s in start..rows {
                    let tok = base_tok + s;
                    for h in 0..h_n {
                        let src = s * w + h * dh;
                        let dst = h * cap * dh + tok * dh;
                        k_out[dst..dst + dh].copy_from_slice(&k[src..src + dh]);
                        v_out[dst..dst + dh].copy_from_slice(&v[src..src + dh]);
                    }
                }
            });
        }
    }

    /// Materialize a whole decode batch in one pass: `caches[b]`'s block
    /// list lands at row `b` of a `[rows, H, cap, dh]` upload pair, each
    /// at the joint capacity `cap`; rows beyond `caches.len()` (batch
    /// padding slots) are zeroed. No per-request slabs are allocated —
    /// the buffers grow to the high-water mark and are reused. All
    /// caches must share one (n_heads, d_head) geometry.
    ///
    /// Stateless: every call re-gathers every row and re-zeroes the
    /// full padding region. The pipelined decode path uses the stateful
    /// [`GatherBuf`] instead, which remembers what each buffer row
    /// holds and downgrades unchanged-prefix refills to delta-append
    /// copies (and zeroing to the previously occupied extent). This
    /// entry point remains for one-shot gathers and as the
    /// reference-oracle the `GatherBuf` property tests compare against.
    pub fn padded_kv_batch_into(
        caches: &[&LayerCache],
        rows: usize,
        cap: usize,
        k_out: &mut Vec<f32>,
        v_out: &mut Vec<f32>,
    ) {
        assert!(caches.len() <= rows, "{} caches > {} batch rows", caches.len(), rows);
        let Some(first) = caches.first() else {
            // An empty batch carries no geometry (n_heads/d_head) to size
            // or zero padding rows with — the zeroing contract above is
            // only honorable for rows == 0.
            assert_eq!(rows, 0, "empty batch cannot have padding rows");
            return;
        };
        let per = first.n_heads * cap * first.d_head;
        let elems = per * rows;
        if k_out.len() < elems {
            k_out.resize(elems, 0.0);
        }
        if v_out.len() < elems {
            v_out.resize(elems, 0.0);
        }
        for (b, c) in caches.iter().enumerate() {
            assert_eq!((c.n_heads, c.d_head), (first.n_heads, first.d_head));
            c.padded_kv_fill(cap, &mut k_out[b * per..(b + 1) * per], &mut v_out[b * per..(b + 1) * per]);
        }
        // Padding rows: the buffers are reused across quanta, so stale
        // rows must be re-zeroed explicitly.
        k_out[caches.len() * per..elems].fill(0.0);
        v_out[caches.len() * per..elems].fill(0.0);
    }

    /// True when every allocated slot at or beyond `len` is exactly zero —
    /// the clean-padding invariant (regression-tested after `compact`).
    pub fn padding_is_zero(&self) -> bool {
        let w = self.row_elems();
        for (bi, &id) in self.blocks.iter().enumerate() {
            let base_tok = bi * BLOCK_TOKENS;
            let live = BLOCK_TOKENS.min(self.len.saturating_sub(base_tok));
            let clean = self.pool.with_kv(id, |k, v| {
                k[live * w..].iter().all(|&x| x == 0.0)
                    && v[live * w..].iter().all(|&x| x == 0.0)
            });
            if !clean {
                return false;
            }
        }
        true
    }

    /// The block that will hold slot `len`, forked first if it is shared
    /// (copy-on-write) or freshly allocated at a block boundary.
    fn writable_tail(&mut self) -> usize {
        let bi = self.len / BLOCK_TOKENS;
        if bi == self.blocks.len() {
            let id = self.pool.alloc(self.row_elems());
            self.blocks.push(id);
            return id;
        }
        let id = self.blocks[bi];
        if self.pool.refs(id) > 1 {
            // Fork carries the zero padding of the source block, so the
            // clean-padding invariant survives the copy.
            let f = self.pool.fork(id);
            self.pool.release(id);
            self.blocks[bi] = f;
            return f;
        }
        id
    }

    /// Append one token's K/V (`[H, dh]` each) at original position `pos`.
    /// The caller must ensure capacity (`grow` first if needed). If the
    /// tail block is shared, only that block is forked — never the prefix.
    pub fn append(&mut self, k_new: &[f32], v_new: &[f32], pos: i32) {
        assert!(self.len < self.cap, "cache full: len={} cap={}", self.len, self.cap);
        assert_eq!(k_new.len(), self.row_elems());
        assert_eq!(v_new.len(), self.row_elems());
        let id = self.writable_tail();
        self.pool.write_row(id, self.len % BLOCK_TOKENS, k_new, v_new);
        self.positions.push(pos);
        self.len += 1;
    }

    /// Keep only the slots in `keep` (ascending, unique, all `< len`),
    /// compacting rows to the front. Positions follow their rows.
    ///
    /// Copy-on-write: fully-retained identity-prefix blocks are kept
    /// as-is (still shared if they were shared). When every block from
    /// the first divergence onward is *solely owned* (refs == 1 — the
    /// common case during `fine_during_decode`, where each step prunes a
    /// private cache), rows are moved **in place** and the vacated tail
    /// is re-zeroed, allocating nothing. Otherwise every row from the
    /// divergence is gathered into fresh zero-filled blocks and the old
    /// blocks are released. Either way the vacated range reads exactly
    /// zero, however large the prune.
    pub fn compact(&mut self, keep: &[usize]) {
        debug_assert!(keep.windows(2).all(|w| w[0] < w[1]), "keep must be ascending");
        if let Some(&last) = keep.last() {
            assert!(last < self.len, "keep index {} out of range {}", last, self.len);
        }
        // Longest identity prefix: rows that stay in place.
        let mut ident = 0;
        while ident < keep.len() && keep[ident] == ident {
            ident += 1;
        }
        if ident == keep.len() && keep.len() == self.len {
            return; // no-op compaction
        }
        let w = self.row_elems();
        let keep_blocks = ident / BLOCK_TOKENS;
        let mut k_buf = vec![0.0f32; w];
        let mut v_buf = vec![0.0f32; w];
        let tail_sole = self.blocks[keep_blocks..]
            .iter()
            .all(|&id| self.pool.refs(id) == 1);
        if tail_sole {
            // In-place fast path: `keep` ascending means dst <= src, so
            // moving rows front-to-back never clobbers an unread source.
            for (dst, &src) in keep.iter().enumerate().skip(keep_blocks * BLOCK_TOKENS) {
                if dst == src {
                    continue;
                }
                let sb = self.blocks[src / BLOCK_TOKENS];
                let ss = src % BLOCK_TOKENS;
                self.pool.with_kv(sb, |k, v| {
                    k_buf.copy_from_slice(&k[ss * w..(ss + 1) * w]);
                    v_buf.copy_from_slice(&v[ss * w..(ss + 1) * w]);
                });
                self.pool
                    .write_row(self.blocks[dst / BLOCK_TOKENS], dst % BLOCK_TOKENS, &k_buf, &v_buf);
            }
            // Drop whole blocks past the new length; re-zero the partial
            // tail of the retained ones (clean-padding invariant).
            let need = keep.len().div_ceil(BLOCK_TOKENS);
            for &id in &self.blocks[need..] {
                self.pool.release(id);
            }
            self.blocks.truncate(need);
            for (bi, &id) in self.blocks.iter().enumerate() {
                let live = keep.len().saturating_sub(bi * BLOCK_TOKENS);
                if live < BLOCK_TOKENS {
                    self.pool.zero_tail(id, live);
                }
            }
        } else {
            let mut new_blocks: Vec<usize> = Vec::new();
            for (dst, &src) in keep.iter().enumerate().skip(keep_blocks * BLOCK_TOKENS) {
                let slot = dst % BLOCK_TOKENS;
                if slot == 0 {
                    new_blocks.push(self.pool.alloc(w));
                }
                let sb = self.blocks[src / BLOCK_TOKENS];
                let ss = src % BLOCK_TOKENS;
                self.pool.with_kv(sb, |k, v| {
                    k_buf.copy_from_slice(&k[ss * w..(ss + 1) * w]);
                    v_buf.copy_from_slice(&v[ss * w..(ss + 1) * w]);
                });
                self.pool.write_row(*new_blocks.last().unwrap(), slot, &k_buf, &v_buf);
            }
            for &id in &self.blocks[keep_blocks..] {
                self.pool.release(id);
            }
            self.blocks.truncate(keep_blocks);
            self.blocks.extend(new_blocks);
        }
        let new_pos: Vec<i32> = keep.iter().map(|&i| self.positions[i]).collect();
        self.positions = new_pos;
        self.len = keep.len();
        // Rows moved: any delta-upload state gathered from this cache
        // is now invalid (the no-op compaction above returns early and
        // keeps the epoch — its rows are untouched).
        self.epoch += 1;
    }

    /// Re-target the logical capacity (next compiled bucket). Paged
    /// storage makes this free: no rows move, no bytes are copied.
    pub fn grow(&mut self, new_cap: usize) {
        assert!(new_cap >= self.len, "grow below live length");
        self.cap = new_cap;
    }
}

/// One layer's KV cache split across the device mesh: shard `s` holds
/// an independent paged block list for heads `[s·H/D, (s+1)·H/D)`, so
/// per-device uploads materialize straight from per-shard blocks and
/// nothing is re-laid-out when sharding. Every shard advances in
/// lockstep (same `len`, `cap`, and positions); `append` splits one
/// full-head row into per-shard chunks (rows are head-major, so shard
/// chunks are contiguous), and `compact` applies one keep set to all
/// shards. With a single shard this is exactly a [`LayerCache`] — the
/// tp_degree = 1 engine path wraps today's caches via
/// [`ShardedLayerCache::from_single`] without copying a byte.
#[derive(Debug, Clone)]
pub struct ShardedLayerCache {
    shards: Vec<LayerCache>,
}

impl ShardedLayerCache {
    /// Wrap a full-head cache as the one-shard (tp_degree = 1) case.
    pub fn from_single(c: LayerCache) -> ShardedLayerCache {
        ShardedLayerCache { shards: vec![c] }
    }

    /// Assemble from per-shard caches (equal length and capacity).
    pub fn from_shards(shards: Vec<LayerCache>) -> ShardedLayerCache {
        assert!(!shards.is_empty(), "a cache needs at least one shard");
        let (len, cap, dh) = (shards[0].len(), shards[0].cap(), shards[0].d_head);
        for s in &shards[1..] {
            assert_eq!((s.len(), s.cap(), s.d_head), (len, cap, dh), "shard drift");
        }
        ShardedLayerCache { shards }
    }

    /// Empty sharded cache: `n_heads` total heads split over `tp` shards,
    /// allocating from the process-wide pool.
    pub fn new(n_heads: usize, d_head: usize, cap: usize, tp: usize) -> ShardedLayerCache {
        assert!(tp >= 1 && n_heads % tp == 0, "heads {} not divisible by tp {}", n_heads, tp);
        let hs = n_heads / tp;
        ShardedLayerCache {
            shards: (0..tp).map(|_| LayerCache::new(hs, d_head, cap)).collect(),
        }
    }

    /// Build from per-shard prefill K/V slabs (`[Hs, src_n, dh]` each),
    /// keeping rows `0..valid` with explicit original positions.
    pub fn from_prefill_shards(
        d_head: usize,
        cap: usize,
        shard_kv: &[(Vec<f32>, Vec<f32>)],
        src_n: usize,
        valid: usize,
        positions: &[i32],
    ) -> ShardedLayerCache {
        assert!(!shard_kv.is_empty());
        let shards = shard_kv
            .iter()
            .map(|(k, v)| {
                let hs = k.len() / (src_n * d_head);
                LayerCache::from_prefill(hs, d_head, cap, k, v, src_n, valid, positions)
            })
            .collect();
        ShardedLayerCache::from_shards(shards)
    }

    pub fn tp(&self) -> usize {
        self.shards.len()
    }

    pub fn shard(&self, s: usize) -> &LayerCache {
        &self.shards[s]
    }

    pub fn shard_mut(&mut self, s: usize) -> &mut LayerCache {
        &mut self.shards[s]
    }

    /// Shard 0 — *the* cache in the single-shard (tp_degree = 1) case.
    pub fn primary(&self) -> &LayerCache {
        &self.shards[0]
    }

    pub fn primary_mut(&mut self) -> &mut LayerCache {
        &mut self.shards[0]
    }

    pub fn len(&self) -> usize {
        self.shards[0].len()
    }

    pub fn is_empty(&self) -> bool {
        self.shards[0].is_empty()
    }

    pub fn cap(&self) -> usize {
        self.shards[0].cap()
    }

    pub fn positions(&self) -> &[i32] {
        self.shards[0].positions()
    }

    pub fn mask(&self) -> Vec<f32> {
        self.shards[0].mask()
    }

    /// Allocated payload bytes summed over shards (identical to the
    /// unsharded footprint: the same rows, split by head range).
    pub fn bytes(&self) -> usize {
        self.shards.iter().map(|c| c.bytes()).sum()
    }

    pub fn grow(&mut self, new_cap: usize) {
        for c in &mut self.shards {
            c.grow(new_cap);
        }
    }

    /// Append one token's full-head K/V row (`[H, dh]` head-major each):
    /// shard `s` receives its contiguous `[Hs·dh]` chunk.
    pub fn append(&mut self, k_new: &[f32], v_new: &[f32], pos: i32) {
        let total: usize = self.shards.iter().map(|c| c.n_heads * c.d_head).sum();
        assert_eq!(k_new.len(), total);
        assert_eq!(v_new.len(), total);
        let mut at = 0;
        for c in &mut self.shards {
            let w = c.n_heads * c.d_head;
            c.append(&k_new[at..at + w], &v_new[at..at + w], pos);
            at += w;
        }
    }

    /// Apply one keep set to every shard (fine pruning prunes *tokens*,
    /// which exist in all head shards).
    pub fn compact(&mut self, keep: &[usize]) {
        for c in &mut self.shards {
            c.compact(keep);
        }
    }

    pub fn padding_is_zero(&self) -> bool {
        self.shards.iter().all(|c| c.padding_is_zero())
    }
}

/// All layers' caches for one request + peak-memory accounting.
#[derive(Debug, Clone, Default)]
pub struct CacheSet {
    pub layers: Vec<ShardedLayerCache>,
    peak_bytes: usize,
}

impl CacheSet {
    pub fn push(&mut self, c: ShardedLayerCache) {
        self.layers.push(c);
        self.update_peak();
    }

    /// Push a full-head cache as a single-shard layer (tp_degree = 1).
    pub fn push_single(&mut self, c: LayerCache) {
        self.push(ShardedLayerCache::from_single(c));
    }

    pub fn bytes(&self) -> usize {
        self.layers.iter().map(|c| c.bytes()).sum()
    }

    pub fn peak_bytes(&self) -> usize {
        self.peak_bytes
    }

    pub fn update_peak(&mut self) {
        self.peak_bytes = self.peak_bytes.max(self.bytes());
    }

    /// Live token count per layer (the pruning trace).
    pub fn live_counts(&self) -> Vec<usize> {
        self.layers.iter().map(|c| c.len()).collect()
    }

    /// Drop every layer's blocks now, returning them to the block pool
    /// through the refcounted drop path (prefix-shared blocks survive
    /// via the prefix-cache entry's own references). The peak watermark
    /// is sealed first so result accounting still reports it — this is
    /// the terminal-cleanup hook: a finished/canceled generation's KV
    /// must not wait for the request object (or a slow stream consumer)
    /// to be torn down.
    pub fn release(&mut self) {
        self.update_peak();
        self.layers.clear();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn filled_in(pool: &BlockPool, n_heads: usize, dh: usize, cap: usize, n: usize) -> LayerCache {
        // K row value = 100*h + i, V = negative of that; positions = 10+i.
        let mut k = vec![0.0f32; n_heads * n * dh];
        let mut v = vec![0.0f32; n_heads * n * dh];
        for h in 0..n_heads {
            for i in 0..n {
                for d in 0..dh {
                    k[h * n * dh + i * dh + d] = (100 * h + i) as f32;
                    v[h * n * dh + i * dh + d] = -((100 * h + i) as f32);
                }
            }
        }
        let mut c = LayerCache::new_in(pool.clone(), n_heads, dh, cap);
        let mut k_row = vec![0.0f32; n_heads * dh];
        let mut v_row = vec![0.0f32; n_heads * dh];
        for i in 0..n {
            for h in 0..n_heads {
                k_row[h * dh..(h + 1) * dh].copy_from_slice(&k[h * n * dh + i * dh..][..dh]);
                v_row[h * dh..(h + 1) * dh].copy_from_slice(&v[h * n * dh + i * dh..][..dh]);
            }
            c.append(&k_row, &v_row, 10 + i as i32);
        }
        c
    }

    fn filled(n_heads: usize, dh: usize, cap: usize, n: usize) -> LayerCache {
        filled_in(&BlockPool::new(), n_heads, dh, cap, n)
    }

    #[test]
    fn from_prefill_copies_rows() {
        let mut k = vec![0.0f32; 2 * 5 * 4];
        let mut v = vec![0.0f32; 2 * 5 * 4];
        for h in 0..2 {
            for i in 0..5 {
                for d in 0..4 {
                    k[h * 5 * 4 + i * 4 + d] = (100 * h + i) as f32;
                    v[h * 5 * 4 + i * 4 + d] = -((100 * h + i) as f32);
                }
            }
        }
        let positions: Vec<i32> = (0..5).map(|i| 10 + i).collect();
        let c = LayerCache::from_prefill(2, 4, 8, &k, &v, 5, 5, &positions);
        assert_eq!(c.len(), 5);
        assert_eq!(c.k_row(1, 3)[0], 103.0);
        assert_eq!(c.v_row(0, 2)[0], -2.0);
        assert_eq!(c.positions(), &[10, 11, 12, 13, 14]);
    }

    #[test]
    fn compact_preserves_position_row_mapping() {
        let mut c = filled(2, 4, 8, 6);
        c.compact(&[0, 2, 5]);
        assert_eq!(c.len(), 3);
        assert_eq!(c.positions(), &[10, 12, 15]);
        assert_eq!(c.k_row(0, 0)[0], 0.0);
        assert_eq!(c.k_row(0, 1)[0], 2.0);
        assert_eq!(c.k_row(0, 2)[0], 5.0);
        assert_eq!(c.k_row(1, 2)[0], 105.0);
        // mask reflects new length
        let m = c.mask();
        assert_eq!(m.iter().filter(|&&x| x > 0.5).count(), 3);
        assert!(c.padding_is_zero());
    }

    #[test]
    fn compact_zeroes_entire_vacated_range() {
        // Regression: the pre-paged layout only zeroed 64 rows past `len`,
        // leaving stale K/V beyond that after a large prune. Paged compact
        // rebuilds the tail into fresh zero-filled blocks, so the whole
        // vacated range reads zero.
        let n = 4 * BLOCK_TOKENS + 7; // several blocks, partial tail
        let mut c = filled(1, 2, n + 8, n);
        c.compact(&[0, 1]); // prune almost everything (>> 64 rows vacated)
        assert_eq!(c.len(), 2);
        assert!(c.padding_is_zero(), "vacated range must read zero");
        let (k, v) = c.padded_kv();
        assert!(k[2 * 2..].iter().all(|&x| x == 0.0));
        assert!(v[2 * 2..].iter().all(|&x| x == 0.0));
    }

    #[test]
    fn append_then_read_back() {
        let mut c = filled(2, 4, 8, 3);
        let k_new = vec![7.0f32; 8];
        let v_new = vec![-7.0f32; 8];
        c.append(&k_new, &v_new, 42);
        assert_eq!(c.len(), 4);
        assert_eq!(c.k_row(0, 3)[0], 7.0);
        assert_eq!(c.k_row(1, 3)[0], 7.0);
        assert_eq!(c.positions().last(), Some(&42));
    }

    #[test]
    fn grow_preserves_rows() {
        let mut c = filled(2, 4, 8, 6);
        c.compact(&[1, 4]);
        c.grow(16);
        assert_eq!(c.cap(), 16);
        assert_eq!(c.len(), 2);
        assert_eq!(c.k_row(0, 0)[0], 1.0);
        assert_eq!(c.k_row(1, 1)[0], 104.0);
        assert_eq!(c.positions(), &[11, 14]);
    }

    #[test]
    #[should_panic(expected = "cache full")]
    fn append_past_capacity_panics() {
        let mut c = filled(1, 2, 3, 3);
        c.append(&[0.0, 0.0], &[0.0, 0.0], 1);
    }

    #[test]
    fn bytes_accounting_is_paged() {
        let pool = BlockPool::new();
        let c = LayerCache::new_in(pool.clone(), 2, 4, 8);
        // No rows appended -> no blocks allocated.
        assert_eq!(c.bytes(), 0);
        // The admission estimate stays the dense upper bound.
        assert_eq!(LayerCache::slab_bytes(2, 4, 8), 2 * 2 * 8 * 4 * 4);
        let c = filled_in(&pool, 2, 4, 8, 3);
        assert_eq!(c.bytes(), block_bytes(2 * 4)); // one block allocated
        assert!(c.bytes() <= LayerCache::slab_bytes(2, 4, BLOCK_TOKENS));
        let mut set = CacheSet::default();
        set.push(c);
        assert_eq!(set.bytes(), set.peak_bytes());
        assert_eq!(set.live_counts(), vec![3]);
    }

    #[test]
    fn peak_tracks_maximum() {
        let pool = BlockPool::new();
        let mut set = CacheSet::default();
        set.push(LayerCache::new_in(pool.clone(), 1, 2, 2 * BLOCK_TOKENS));
        let before = set.peak_bytes();
        // Appending across a block boundary allocates more blocks.
        for i in 0..BLOCK_TOKENS + 1 {
            set.layers[0].append(&[1.0, 1.0], &[2.0, 2.0], i as i32);
        }
        set.update_peak();
        assert!(set.peak_bytes() > before);
    }

    #[test]
    fn clone_shares_blocks_and_cow_isolates_writers() {
        let pool = BlockPool::new();
        let a = filled_in(&pool, 1, 2, 64, BLOCK_TOKENS + 4);
        let mut b = a.clone();
        assert_eq!(a.block_ids(), b.block_ids());
        assert_eq!(pool.stats().shared, 2);
        // Appending to the clone forks only the partial tail block.
        b.append(&[9.0, 9.0], &[9.0, 9.0], 99);
        assert_eq!(a.block_ids()[0], b.block_ids()[0], "full prefix block still shared");
        assert_ne!(a.block_ids()[1], b.block_ids()[1], "tail block forked");
        assert_eq!(a.len(), BLOCK_TOKENS + 4);
        assert_eq!(a.k_row(0, BLOCK_TOKENS + 3)[0], (BLOCK_TOKENS + 3) as f32);
        // Compacting the clone never touches the original's rows.
        b.compact(&[0, 1, 2]);
        assert_eq!(a.k_row(0, 5)[0], 5.0);
        assert!(a.padding_is_zero() && b.padding_is_zero());
        drop(a);
        drop(b);
        assert_eq!(pool.stats().used, 0, "all blocks returned to the pool");
    }

    #[test]
    fn padded_kv_into_is_high_water_and_sliced() {
        let c = filled(2, 3, 8, 5);
        let mut k = vec![9.0f32; 1000]; // oversized scratch from a prior, bigger bucket
        let mut v = vec![9.0f32; 1000];
        c.padded_kv_into(&mut k, &mut v);
        assert_eq!(k.len(), 1000, "scratch is never shrunk");
        let elems = 2 * 8 * 3;
        let (kf, vf) = c.padded_kv();
        assert_eq!(&k[..elems], &kf[..]);
        assert_eq!(&v[..elems], &vf[..]);
        assert_eq!(k[elems], 9.0, "bytes past the slice untouched");
    }

    #[test]
    fn padded_kv_fill_at_joint_cap() {
        // Gathering at a larger joint capacity re-strides rows: head h's
        // row i lands at h*cap*dh + i*dh for the *joint* cap.
        let c = filled(2, 3, 8, 5);
        let cap = 16;
        let mut k = vec![7.0f32; 2 * cap * 3];
        let mut v = vec![7.0f32; 2 * cap * 3];
        c.padded_kv_fill(cap, &mut k, &mut v);
        for h in 0..2 {
            for i in 0..5 {
                assert_eq!(k[h * cap * 3 + i * 3], (100 * h + i) as f32);
                assert_eq!(v[h * cap * 3 + i * 3], -((100 * h + i) as f32));
            }
            for i in 5..cap {
                assert_eq!(k[h * cap * 3 + i * 3], 0.0, "padding must be zeroed");
            }
        }
    }

    #[test]
    fn padded_kv_batch_matches_per_request_gathers() {
        let pool = BlockPool::new();
        let a = filled_in(&pool, 2, 3, 8, 5);
        let b = filled_in(&pool, 2, 3, 8, 3);
        let cap = 8;
        let rows = 4; // 2 live + 2 padding rows
        let per = 2 * cap * 3;
        let mut k = vec![1.0f32; rows * per]; // stale contents everywhere
        let mut v = vec![1.0f32; rows * per];
        LayerCache::padded_kv_batch_into(&[&a, &b], rows, cap, &mut k, &mut v);
        let mut ka = vec![0.0; per];
        let mut va = vec![0.0; per];
        a.padded_kv_fill(cap, &mut ka, &mut va);
        assert_eq!(&k[..per], &ka[..]);
        assert_eq!(&v[..per], &va[..]);
        b.padded_kv_fill(cap, &mut ka, &mut va);
        assert_eq!(&k[per..2 * per], &ka[..]);
        assert_eq!(&v[per..2 * per], &va[..]);
        assert!(k[2 * per..].iter().all(|&x| x == 0.0), "padding rows re-zeroed");
        assert!(v[2 * per..].iter().all(|&x| x == 0.0));
    }

    #[test]
    fn from_strided_rows_gathers_and_keeps_positions() {
        // [H=2, src_n=5, dh=3] slab with value 100*h + i per row.
        let (h_n, src_n, dh) = (2, 5, 3);
        let mut k = vec![0.0f32; h_n * src_n * dh];
        let mut v = vec![0.0f32; h_n * src_n * dh];
        for h in 0..h_n {
            for i in 0..src_n {
                for d in 0..dh {
                    k[h * src_n * dh + i * dh + d] = (100 * h + i) as f32;
                    v[h * src_n * dh + i * dh + d] = -((100 * h + i) as f32);
                }
            }
        }
        let pool = BlockPool::new();
        let c = LayerCache::from_strided_rows(pool, h_n, dh, 8, &k, &v, src_n, &[1, 4]);
        assert_eq!(c.len(), 2);
        assert_eq!(c.positions(), &[1, 4]);
        assert_eq!(c.k_row(0, 0)[0], 1.0);
        assert_eq!(c.k_row(1, 1)[0], 104.0);
        assert_eq!(c.v_row(1, 0)[0], -101.0);
        assert!(c.padding_is_zero());
    }

    #[test]
    fn compact_solely_owned_is_in_place() {
        // Regression (refs == 1 fast path): compacting a cache whose tail
        // blocks are solely owned must reuse those blocks instead of
        // rewriting every row into fresh ones.
        let n = 3 * BLOCK_TOKENS + 5;
        let pool = BlockPool::new();
        let mut c = filled_in(&pool, 2, 4, n + 8, n);
        let before = c.block_ids().to_vec();
        let slots_before = pool.total_slots();
        let keep: Vec<usize> = (0..n).step_by(3).collect(); // scattered
        c.compact(&keep);
        assert_eq!(c.len(), keep.len());
        assert_eq!(
            c.block_ids(),
            &before[..keep.len().div_ceil(BLOCK_TOKENS)],
            "in-place compact must keep a prefix of the original blocks"
        );
        assert_eq!(pool.total_slots(), slots_before, "no fresh allocation");
        for (r, &src) in keep.iter().enumerate() {
            assert_eq!(c.k_row(0, r)[0], src as f32);
            assert_eq!(c.k_row(1, r)[0], (100 + src) as f32);
            assert_eq!(c.positions()[r], 10 + src as i32);
        }
        assert!(c.padding_is_zero(), "vacated tail must be re-zeroed");
    }

    #[test]
    fn compact_shared_tail_still_copies() {
        // A shared tail (refcount > 1) must take the COW slow path: the
        // clone's rows survive the original's compaction untouched.
        let pool = BlockPool::new();
        let mut a = filled_in(&pool, 1, 2, 4 * BLOCK_TOKENS, 2 * BLOCK_TOKENS);
        let b = a.clone();
        let before = b.block_ids().to_vec();
        a.compact(&[0, 3, BLOCK_TOKENS + 1]);
        assert_eq!(b.block_ids(), &before[..], "clone's blocks untouched");
        for i in 0..2 * BLOCK_TOKENS {
            assert_eq!(b.k_row(0, i)[0], i as f32, "clone row {} perturbed", i);
        }
        assert_eq!(a.k_row(0, 1)[0], 3.0);
        assert!(a.padding_is_zero() && b.padding_is_zero());
    }

    #[test]
    fn sharded_cache_matches_full_head_cache() {
        // Appending full-head rows into a 2-shard cache lands each head
        // range in its own block list, bit-identical to the full cache.
        let (h_n, dh, cap, n) = (4, 3, 2 * BLOCK_TOKENS, BLOCK_TOKENS + 3);
        let pool = BlockPool::new();
        let full = filled_in(&pool, h_n, dh, cap, n);
        let mut sc = ShardedLayerCache::new(h_n, dh, cap, 2);
        assert_eq!(sc.tp(), 2);
        let mut row_k = vec![0.0f32; h_n * dh];
        let mut row_v = vec![0.0f32; h_n * dh];
        for i in 0..n {
            for h in 0..h_n {
                row_k[h * dh..(h + 1) * dh].copy_from_slice(&full.k_row(h, i));
                row_v[h * dh..(h + 1) * dh].copy_from_slice(&full.v_row(h, i));
            }
            sc.append(&row_k, &row_v, full.positions()[i]);
        }
        assert_eq!(sc.len(), full.len());
        assert_eq!(sc.positions(), full.positions());
        // Shard s, head h == full cache head s*2 + h.
        for s in 0..2 {
            for h in 0..2 {
                for i in 0..n {
                    assert_eq!(sc.shard(s).k_row(h, i), full.k_row(s * 2 + h, i));
                    assert_eq!(sc.shard(s).v_row(h, i), full.v_row(s * 2 + h, i));
                }
            }
        }
        // compact/grow stay in lockstep across shards.
        sc.compact(&[0, 2, BLOCK_TOKENS]);
        assert_eq!(sc.len(), 3);
        assert_eq!(sc.positions(), &[10, 12, 10 + BLOCK_TOKENS as i32]);
        assert_eq!(sc.shard(1).k_row(0, 1), full.k_row(2, 2));
        sc.grow(4 * BLOCK_TOKENS);
        assert_eq!(sc.cap(), 4 * BLOCK_TOKENS);
        assert_eq!(sc.shard(0).cap(), 4 * BLOCK_TOKENS);
        assert!(sc.padding_is_zero());
    }

    #[test]
    fn sharded_single_is_transparent_wrapper() {
        let pool = BlockPool::new();
        let c = filled_in(&pool, 2, 4, 8, 3);
        let bytes = c.bytes();
        let ids = c.block_ids().to_vec();
        let sc = ShardedLayerCache::from_single(c);
        assert_eq!(sc.tp(), 1);
        assert_eq!(sc.len(), 3);
        assert_eq!(sc.bytes(), bytes);
        assert_eq!(sc.primary().block_ids(), &ids[..], "no copy on wrap");
    }

    #[test]
    fn id_epoch_form_the_delta_validity_tuple() {
        let pool = BlockPool::new();
        let mut a = filled_in(&pool, 1, 2, 64, 5);
        let id0 = a.id();
        let ep0 = a.epoch();
        // append + grow preserve the live prefix -> epoch unchanged.
        a.append(&[1.0, 1.0], &[2.0, 2.0], 99);
        a.grow(128);
        assert_eq!((a.id(), a.epoch()), (id0, ep0));
        // A clone may diverge through COW: it must not share the id.
        let b = a.clone();
        assert_ne!(b.id(), a.id());
        // compact moves rows -> epoch bump; identity no-op keeps it.
        a.compact(&[0, 1, 2, 3, 4, 5]);
        assert_eq!(a.epoch(), ep0, "identity compaction leaves rows untouched");
        a.compact(&[0, 2]);
        assert_eq!(a.epoch(), ep0 + 1);
    }

    #[test]
    fn fill_ext_zeroes_exactly_the_stale_extent() {
        let c = filled(2, 3, 8, 5);
        let cap = 8;
        let mut k = vec![9.0f32; 2 * cap * 3]; // sentinel everywhere
        let mut v = vec![9.0f32; 2 * cap * 3];
        // Previous occupant wrote 6 rows: slots 5..6 must be zeroed,
        // slots 6.. are trusted (and must keep the sentinel).
        c.padded_kv_fill_ext(cap, &mut k, &mut v, 6);
        for h in 0..2 {
            for i in 0..5 {
                assert_eq!(k[h * cap * 3 + i * 3], (100 * h + i) as f32);
            }
            assert_eq!(k[h * cap * 3 + 5 * 3], 0.0, "stale band re-zeroed");
            for i in 6..cap {
                assert_eq!(k[h * cap * 3 + i * 3], 9.0, "never-occupied rows untouched");
                assert_eq!(v[h * cap * 3 + i * 3], 9.0);
            }
        }
        // prev_rows == cap reproduces the stateless fill exactly.
        let mut k2 = vec![9.0f32; 2 * cap * 3];
        let mut v2 = vec![9.0f32; 2 * cap * 3];
        c.padded_kv_fill_ext(cap, &mut k2, &mut v2, cap);
        let mut kf = vec![0.0f32; 2 * cap * 3];
        let mut vf = vec![0.0f32; 2 * cap * 3];
        c.padded_kv_fill(cap, &mut kf, &mut vf);
        assert_eq!(k2, kf);
        assert_eq!(v2, vf);
    }

    #[test]
    fn fill_tail_completes_a_prefix_fill() {
        let pool = BlockPool::new();
        let cap = 2 * BLOCK_TOKENS;
        let mut c = filled_in(&pool, 2, 3, cap, BLOCK_TOKENS + 2);
        let mut k = vec![0.0f32; 2 * cap * 3];
        let mut v = vec![0.0f32; 2 * cap * 3];
        c.padded_kv_fill(cap, &mut k, &mut v);
        let from = c.len();
        // Append two rows (crossing nothing / staying in the tail block).
        c.append(&[7.0; 6], &[-7.0; 6], 70);
        c.append(&[8.0; 6], &[-8.0; 6], 80);
        c.padded_kv_fill_tail(cap, from, &mut k, &mut v);
        let mut kf = vec![0.0f32; 2 * cap * 3];
        let mut vf = vec![0.0f32; 2 * cap * 3];
        c.padded_kv_fill(cap, &mut kf, &mut vf);
        assert_eq!(k, kf, "prefix fill + tail delta must equal a fresh fill");
        assert_eq!(v, vf);
    }

    #[test]
    fn padded_kv_matches_rows() {
        let c = filled(2, 3, 8, 5);
        let (k, v) = c.padded_kv();
        assert_eq!(k.len(), 2 * 8 * 3);
        for h in 0..2 {
            for i in 0..5 {
                assert_eq!(k[h * 8 * 3 + i * 3], (100 * h + i) as f32);
                assert_eq!(v[h * 8 * 3 + i * 3], -((100 * h + i) as f32));
            }
            for i in 5..8 {
                assert_eq!(k[h * 8 * 3 + i * 3], 0.0);
            }
        }
    }
}
