//! Shared AV-prefix KV cache: a radix trie of frozen, refcounted prefix
//! entries over the [`super::BlockPool`].
//!
//! FastAV's deployed *positional* global pruning makes the post-prune AV
//! prefix KV query-independent: the keep rule depends only on token
//! positions and layout, never on the question. Every request over the
//! same sample/layout/pruning-config therefore produces bit-identical
//! front-layer K/V for the audio-visual prefix — by far the largest token
//! block an AV-LLM ingests — so the serving stack computes it once and
//! shares it.
//!
//! Keying: entries are grouped by a *config key* (global-pruning config +
//! split depth + layout + model fingerprint) and, within a config, stored
//! in a token **trie** keyed by the tokenized prefix. Lookup walks the
//! request's prefix tokens and returns the deepest entry on the path
//! (longest-prefix match), which lets a request resume mid-sequence from
//! any covered prefix length.
//!
//! Lifetime: a hit takes a [`PrefixLease`] (RAII) that pins the entry
//! against eviction while a generation uses it; eviction is LRU over
//! lease-free entries under a configurable byte budget. Entry payloads
//! are [`LayerCache`]s whose blocks live in the shared pool, so "evicted"
//! blocks are only recycled once the last borrowing request drops them —
//! no use-after-free by construction (property-tested in
//! `rust/tests/test_prefix.rs`).
//!
//! Exposure: `GET /v1/pool` reports `stats()`, `POST /v1/cache/flush`
//! calls [`PrefixCache::flush`], and [`PrefixCache::bind_metrics`] keeps
//! the `fastav_prefix_cache_*` counters and `fastav_kv_blocks_*` gauges
//! live in `/metrics`.

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

use crate::metrics::{Counter, Gauge, Registry};

use super::block::BlockPool;
use super::LayerCache;

// ------------------------------------------------------------- hashing

const FNV_OFFSET: u64 = 0xcbf2_9ce4_8422_2325;
const FNV_PRIME: u64 = 0x0000_0100_0000_01b3;

/// FNV-1a over a `u32` stream (deterministic across runs/platforms, so
/// cache keys are stable and loggable).
pub fn hash_tokens(seed: u64, tokens: &[u32]) -> u64 {
    let mut h = FNV_OFFSET ^ seed;
    for &t in tokens {
        for b in t.to_le_bytes() {
            h ^= b as u64;
            h = h.wrapping_mul(FNV_PRIME);
        }
    }
    h
}

/// Combine already-hashed parts into one key.
pub fn hash_mix(parts: &[u64]) -> u64 {
    let mut h = FNV_OFFSET;
    for &p in parts {
        for b in p.to_le_bytes() {
            h ^= b as u64;
            h = h.wrapping_mul(FNV_PRIME);
        }
    }
    h
}

// ---------------------------------------------------------------- trie

#[derive(Default)]
struct TrieNode {
    children: HashMap<u32, usize>,
    /// Full entry key when a cached prefix ends at this node.
    key: Option<u64>,
}

/// Token radix trie for one config key. Nodes are arena-allocated;
/// removal clears the entry marker (interior nodes are retained — they
/// are a few machine words each and bounded by inserted prefixes).
#[derive(Default)]
struct Trie {
    nodes: Vec<TrieNode>,
}

impl Trie {
    fn new() -> Trie {
        Trie { nodes: vec![TrieNode::default()] }
    }

    fn insert(&mut self, tokens: &[u32], key: u64) {
        let mut at = 0;
        for &t in tokens {
            at = match self.nodes[at].children.get(&t) {
                Some(&n) => n,
                None => {
                    self.nodes.push(TrieNode::default());
                    let n = self.nodes.len() - 1;
                    self.nodes[at].children.insert(t, n);
                    n
                }
            };
        }
        self.nodes[at].key = Some(key);
    }

    /// Deepest entry key along the path of `tokens` (longest-prefix match).
    fn longest(&self, tokens: &[u32]) -> Option<u64> {
        let mut at = 0;
        let mut best = self.nodes[0].key;
        for &t in tokens {
            match self.nodes[at].children.get(&t) {
                Some(&n) => {
                    at = n;
                    if self.nodes[at].key.is_some() {
                        best = self.nodes[at].key;
                    }
                }
                None => break,
            }
        }
        best
    }

    fn remove(&mut self, tokens: &[u32]) {
        let mut at = 0;
        for &t in tokens {
            match self.nodes[at].children.get(&t) {
                Some(&n) => at = n,
                None => return,
            }
        }
        self.nodes[at].key = None;
    }
}

// --------------------------------------------------------------- entry

/// One frozen AV-prefix: everything `ModelEngine::begin_generation` needs
/// to resume a covered request mid-sequence.
pub struct PrefixEntry {
    /// Tokens covered (`prompt[..prefix_len]`).
    pub prefix_len: usize,
    /// Per front layer (`0..g`): K/V rows for **all** prefix positions —
    /// what the resumed text suffix attends to (global pruning removes
    /// tokens *at* the split layer, so layers below it saw every token).
    pub full_layers: Vec<LayerCache>,
    /// Per front layer: K/V rows for keep∩prefix only — the rows a
    /// generation's own decode-path front caches start from.
    pub keep_layers: Vec<LayerCache>,
    /// Hidden rows after the front half for keep∩prefix, `[rows, d_model]`.
    pub h_keep: Vec<f32>,
    /// Original positions of the keep∩prefix rows (ascending).
    pub keep_positions: Vec<i32>,
    /// Payload bytes (block payloads counted once + hidden rows).
    pub bytes: usize,
}

impl PrefixEntry {
    /// Fill in `bytes` from the payloads.
    pub fn finalize(mut self) -> PrefixEntry {
        let layer_bytes: usize = self
            .full_layers
            .iter()
            .chain(self.keep_layers.iter())
            .map(|c| c.bytes())
            .sum();
        self.bytes = layer_bytes + self.h_keep.len() * std::mem::size_of::<f32>();
        self
    }
}

struct Slot {
    entry: Arc<PrefixEntry>,
    tokens: Vec<u32>,
    cfg: u64,
    /// Outstanding leases (pins against eviction).
    active: usize,
    last_used: u64,
}

#[derive(Default)]
struct Inner {
    tries: HashMap<u64, Trie>,
    slots: HashMap<u64, Slot>,
    bytes: usize,
    tick: u64,
}

/// Counter/gauge handles bound by [`PrefixCache::bind_metrics`].
struct MetricSinks {
    hits: Arc<Counter>,
    misses: Arc<Counter>,
    evictions: Arc<Counter>,
    entries_g: Arc<Gauge>,
    bytes_g: Arc<Gauge>,
    blocks_used: Arc<Gauge>,
    blocks_shared: Arc<Gauge>,
    blocks_free: Arc<Gauge>,
}

/// Point-in-time cache accounting (the `/v1/pool` payload).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct PrefixCacheStats {
    pub entries: usize,
    pub bytes: usize,
    pub active_leases: usize,
    pub hits: u64,
    pub misses: u64,
    pub evictions: u64,
    pub insertions: u64,
}

/// Process-wide prefix cache. Thread-safe (`&self` everywhere); shared
/// across replica threads behind an `Arc`.
pub struct PrefixCache {
    inner: Mutex<Inner>,
    pool: BlockPool,
    /// Eviction budget over entry payload bytes; `usize::MAX` = unlimited.
    budget_bytes: usize,
    hits: AtomicU64,
    misses: AtomicU64,
    evictions: AtomicU64,
    insertions: AtomicU64,
    sinks: Mutex<Option<MetricSinks>>,
}

impl PrefixCache {
    /// `budget_bytes == 0` means unlimited (flush/eviction still work).
    pub fn new(budget_bytes: usize) -> PrefixCache {
        Self::new_in(BlockPool::global(), budget_bytes)
    }

    pub fn new_in(pool: BlockPool, budget_bytes: usize) -> PrefixCache {
        PrefixCache {
            inner: Mutex::new(Inner::default()),
            pool,
            budget_bytes: if budget_bytes == 0 { usize::MAX } else { budget_bytes },
            hits: AtomicU64::new(0),
            misses: AtomicU64::new(0),
            evictions: AtomicU64::new(0),
            insertions: AtomicU64::new(0),
            sinks: Mutex::new(None),
        }
    }

    /// The block pool entry payloads must allocate from.
    pub fn pool(&self) -> &BlockPool {
        &self.pool
    }

    pub fn budget_bytes(&self) -> usize {
        self.budget_bytes
    }

    /// Bind the `fastav_prefix_cache_*` / `fastav_kv_blocks_*` series so
    /// every cache operation keeps `/metrics` current.
    pub fn bind_metrics(&self, metrics: &Registry) {
        *self.sinks.lock().unwrap() = Some(MetricSinks {
            hits: metrics.counter("fastav_prefix_cache_hits_total"),
            misses: metrics.counter("fastav_prefix_cache_misses_total"),
            evictions: metrics.counter("fastav_prefix_cache_evictions_total"),
            entries_g: metrics.gauge("fastav_prefix_cache_entries"),
            bytes_g: metrics.gauge("fastav_prefix_cache_bytes"),
            blocks_used: metrics.gauge("fastav_kv_blocks_used"),
            blocks_shared: metrics.gauge("fastav_kv_blocks_shared"),
            blocks_free: metrics.gauge("fastav_kv_blocks_free"),
        });
        self.refresh_gauges();
    }

    /// Re-export the entry/byte gauges and the pool's `kv_blocks_*`
    /// gauges. Called by cache operations and periodically by replica
    /// threads (block usage also drifts with ordinary appends/compacts).
    pub fn refresh_gauges(&self) {
        let sinks = self.sinks.lock().unwrap();
        if let Some(s) = sinks.as_ref() {
            let (entries, bytes) = {
                let inner = self.inner.lock().unwrap();
                (inner.slots.len(), inner.bytes)
            };
            s.entries_g.set(entries as u64);
            s.bytes_g.set(bytes as u64);
            let ps = self.pool.stats();
            s.blocks_used.set(ps.used as u64);
            s.blocks_shared.set(ps.shared as u64);
            s.blocks_free.set(ps.free as u64);
        }
    }

    fn count_hit(&self) {
        self.hits.fetch_add(1, Ordering::Relaxed);
        if let Some(s) = self.sinks.lock().unwrap().as_ref() {
            s.hits.inc();
        }
    }

    fn count_miss(&self) {
        self.misses.fetch_add(1, Ordering::Relaxed);
        if let Some(s) = self.sinks.lock().unwrap().as_ref() {
            s.misses.inc();
        }
    }

    /// Longest-prefix lookup; a hit pins the entry with a lease. Counts a
    /// hit or a miss.
    pub fn lookup(self: &Arc<Self>, cfg: u64, tokens: &[u32]) -> Option<PrefixLease> {
        self.lookup_inner(cfg, tokens, false)
    }

    /// Exact-prefix lookup: a hit only when an entry covers *precisely*
    /// `tokens`. The engine resumes only from exact entries (budget-
    /// matched keep rules select over the whole AV set), and counting
    /// hits here — not on partial matches that fall back to full
    /// prefill — keeps the hit/miss counters honest for operators.
    pub fn lookup_exact(self: &Arc<Self>, cfg: u64, tokens: &[u32]) -> Option<PrefixLease> {
        self.lookup_inner(cfg, tokens, true)
    }

    fn lookup_inner(self: &Arc<Self>, cfg: u64, tokens: &[u32], exact: bool) -> Option<PrefixLease> {
        let exact_key = hash_mix(&[cfg, hash_tokens(0, tokens)]);
        let found = {
            let mut inner = self.inner.lock().unwrap();
            inner.tick += 1;
            let tick = inner.tick;
            let key = if exact {
                inner.slots.contains_key(&exact_key).then_some(exact_key)
            } else {
                inner.tries.get(&cfg).and_then(|t| t.longest(tokens))
            };
            key.and_then(|key| {
                inner.slots.get_mut(&key).map(|slot| {
                    slot.active += 1;
                    slot.last_used = tick;
                    (key, Arc::clone(&slot.entry))
                })
            })
        };
        match found {
            Some((key, entry)) => {
                self.count_hit();
                Some(PrefixLease { cache: Arc::clone(self), key, entry })
            }
            None => {
                self.count_miss();
                None
            }
        }
    }

    /// Exact-prefix probe without lease or hit/miss accounting —
    /// admission uses it to split a request's estimate into shared vs
    /// unique bytes, so it must mirror [`Self::lookup_exact`] (a
    /// partial-coverage entry would credit sharing the resume never
    /// uses). Returns `(entry key, entry bytes)`.
    pub fn peek(&self, cfg: u64, tokens: &[u32]) -> Option<(u64, usize)> {
        let key = hash_mix(&[cfg, hash_tokens(0, tokens)]);
        let inner = self.inner.lock().unwrap();
        inner.slots.get(&key).map(|s| (key, s.entry.bytes))
    }

    /// Insert a frozen entry for `tokens` under `cfg`; no-op if an entry
    /// for the exact prefix already exists (first writer wins — payloads
    /// are deterministic, so both are identical). Evicts LRU lease-free
    /// entries afterwards if the byte budget is exceeded.
    pub fn insert(&self, cfg: u64, tokens: &[u32], entry: PrefixEntry) -> bool {
        debug_assert!(
            entry
                .full_layers
                .iter()
                .chain(entry.keep_layers.iter())
                .all(|c| c.pool().same_pool(&self.pool)),
            "entry blocks must come from the cache's pool"
        );
        let inserted = {
            let mut inner = self.inner.lock().unwrap();
            let key = hash_mix(&[cfg, hash_tokens(0, tokens)]);
            if inner.slots.contains_key(&key) {
                false
            } else {
                inner.tick += 1;
                let tick = inner.tick;
                inner.bytes += entry.bytes;
                inner.slots.insert(
                    key,
                    Slot {
                        entry: Arc::new(entry),
                        tokens: tokens.to_vec(),
                        cfg,
                        active: 0,
                        last_used: tick,
                    },
                );
                inner.tries.entry(cfg).or_insert_with(Trie::new).insert(tokens, key);
                Self::evict_over_budget(&mut inner, self.budget_bytes, &self.evictions);
                true
            }
        };
        if inserted {
            self.insertions.fetch_add(1, Ordering::Relaxed);
        }
        self.refresh_gauges();
        if inserted {
            if let Some(s) = self.sinks.lock().unwrap().as_ref() {
                // Evictions triggered by this insert are already in the
                // atomic; mirror the delta into the counter series.
                let total = self.evictions.load(Ordering::Relaxed);
                let exported = s.evictions.get();
                if total > exported {
                    s.evictions.add(total - exported);
                }
            }
        }
        inserted
    }

    fn evict_over_budget(inner: &mut Inner, budget: usize, evictions: &AtomicU64) {
        while inner.bytes > budget {
            let victim = inner
                .slots
                .iter()
                .filter(|(_, s)| s.active == 0)
                .min_by_key(|(_, s)| s.last_used)
                .map(|(&k, _)| k);
            let Some(key) = victim else { break };
            Self::evict_key(inner, key);
            evictions.fetch_add(1, Ordering::Relaxed);
        }
    }

    fn evict_key(inner: &mut Inner, key: u64) {
        if let Some(slot) = inner.slots.remove(&key) {
            inner.bytes = inner.bytes.saturating_sub(slot.entry.bytes);
            if let Some(trie) = inner.tries.get_mut(&slot.cfg) {
                trie.remove(&slot.tokens);
            }
            // Dropping the Arc releases the blocks once the last
            // in-flight borrower (cloned LayerCache / outstanding lease
            // upgrade) lets go — never before.
        }
    }

    /// Drop every lease-free entry (the `POST /v1/cache/flush` endpoint).
    /// Returns `(entries_evicted, bytes_freed)`.
    pub fn flush(&self) -> (usize, usize) {
        let (n, freed) = {
            let mut inner = self.inner.lock().unwrap();
            let victims: Vec<u64> = inner
                .slots
                .iter()
                .filter(|(_, s)| s.active == 0)
                .map(|(&k, _)| k)
                .collect();
            let before = inner.bytes;
            for key in &victims {
                Self::evict_key(&mut inner, *key);
            }
            (victims.len(), before - inner.bytes)
        };
        self.evictions.fetch_add(n as u64, Ordering::Relaxed);
        if let Some(s) = self.sinks.lock().unwrap().as_ref() {
            s.evictions.add(n as u64);
        }
        self.refresh_gauges();
        (n, freed)
    }

    fn release_lease(&self, key: u64) {
        let mut inner = self.inner.lock().unwrap();
        if let Some(slot) = inner.slots.get_mut(&key) {
            slot.active = slot.active.saturating_sub(1);
        }
    }

    pub fn stats(&self) -> PrefixCacheStats {
        let (entries, bytes, active) = {
            let inner = self.inner.lock().unwrap();
            (
                inner.slots.len(),
                inner.bytes,
                inner.slots.values().map(|s| s.active).sum(),
            )
        };
        PrefixCacheStats {
            entries,
            bytes,
            active_leases: active,
            hits: self.hits.load(Ordering::Relaxed),
            misses: self.misses.load(Ordering::Relaxed),
            evictions: self.evictions.load(Ordering::Relaxed),
            insertions: self.insertions.load(Ordering::Relaxed),
        }
    }
}

/// RAII pin on a cache entry: holds the payload `Arc` and decrements the
/// entry's active count (making it evictable again) on drop.
pub struct PrefixLease {
    cache: Arc<PrefixCache>,
    key: u64,
    entry: Arc<PrefixEntry>,
}

impl PrefixLease {
    pub fn entry(&self) -> &PrefixEntry {
        &self.entry
    }

    pub fn key(&self) -> u64 {
        self.key
    }
}

impl Drop for PrefixLease {
    fn drop(&mut self) {
        self.cache.release_lease(self.key);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn entry_with(pool: &BlockPool, rows: usize) -> PrefixEntry {
        let mut c = LayerCache::new_in(pool.clone(), 1, 2, rows.max(1));
        for i in 0..rows {
            c.append(&[i as f32, 0.0], &[0.0, i as f32], i as i32);
        }
        PrefixEntry {
            prefix_len: rows,
            full_layers: vec![c.clone()],
            keep_layers: vec![c],
            h_keep: vec![0.5; rows],
            keep_positions: (0..rows as i32).collect(),
            bytes: 0,
        }
        .finalize()
    }

    #[test]
    fn longest_prefix_match() {
        let pool = BlockPool::new();
        let cache = Arc::new(PrefixCache::new_in(pool.clone(), 0));
        let cfg = 7;
        assert!(cache.insert(cfg, &[1, 2, 3], entry_with(&pool, 3)));
        assert!(cache.insert(cfg, &[1, 2, 3, 4, 5], entry_with(&pool, 5)));
        // Exact longer prefix wins.
        let lease = cache.lookup(cfg, &[1, 2, 3, 4, 5, 99]).unwrap();
        assert_eq!(lease.entry().prefix_len, 5);
        // Shorter coverage still matches.
        let lease2 = cache.lookup(cfg, &[1, 2, 3, 8]).unwrap();
        assert_eq!(lease2.entry().prefix_len, 3);
        // Different config sees nothing.
        assert!(cache.lookup(8, &[1, 2, 3]).is_none());
        let s = cache.stats();
        assert_eq!((s.hits, s.misses, s.entries), (2, 1, 2));
    }

    #[test]
    fn exact_lookup_rejects_partial_coverage() {
        let pool = BlockPool::new();
        let cache = Arc::new(PrefixCache::new_in(pool.clone(), 0));
        cache.insert(1, &[1, 2], entry_with(&pool, 2));
        // Longest-match sees the shorter entry; exact does not — and the
        // exact miss is counted as a miss, not a hit.
        assert!(cache.lookup(1, &[1, 2, 3]).is_some());
        assert!(cache.lookup_exact(1, &[1, 2, 3]).is_none());
        assert!(cache.lookup_exact(1, &[1, 2]).is_some());
        let s = cache.stats();
        assert_eq!((s.hits, s.misses), (2, 1));
    }

    #[test]
    fn duplicate_insert_is_noop() {
        let pool = BlockPool::new();
        let cache = Arc::new(PrefixCache::new_in(pool.clone(), 0));
        assert!(cache.insert(1, &[5, 6], entry_with(&pool, 2)));
        assert!(!cache.insert(1, &[5, 6], entry_with(&pool, 2)));
        assert_eq!(cache.stats().entries, 1);
    }

    #[test]
    fn lru_eviction_skips_leased_entries() {
        let pool = BlockPool::new();
        let per_entry = entry_with(&pool, 2).bytes;
        // Budget fits exactly two entries.
        let cache = Arc::new(PrefixCache::new_in(pool.clone(), 2 * per_entry));
        cache.insert(1, &[1], entry_with(&pool, 2));
        cache.insert(1, &[2], entry_with(&pool, 2));
        // Pin [1]; touch nothing else, then overflow.
        let lease = cache.lookup(1, &[1]).unwrap();
        cache.insert(1, &[3], entry_with(&pool, 2));
        let s = cache.stats();
        assert_eq!(s.evictions, 1);
        // [2] (LRU among lease-free) was evicted; [1] survived its pin.
        assert!(cache.lookup(1, &[2, 9]).is_none());
        assert!(cache.lookup(1, &[1, 9]).is_some());
        drop(lease);
        let (flushed, freed) = cache.flush();
        assert_eq!(flushed, 2);
        assert!(freed > 0);
        assert_eq!(cache.stats().entries, 0);
    }

    #[test]
    fn evicted_entry_blocks_survive_borrowers() {
        let pool = BlockPool::new();
        let cache = Arc::new(PrefixCache::new_in(pool.clone(), 0));
        cache.insert(1, &[1, 2], entry_with(&pool, 2));
        let lease = cache.lookup(1, &[1, 2]).unwrap();
        // Borrow the payload the way a generation does: clone the cache.
        let borrowed = lease.entry().keep_layers[0].clone();
        drop(lease);
        cache.flush();
        assert_eq!(cache.stats().entries, 0);
        // The borrowed rows are still readable (blocks refcounted).
        assert_eq!(borrowed.k_row(0, 1)[0], 1.0);
        drop(borrowed);
        assert_eq!(pool.stats().used, 0, "blocks recycled after last borrower");
    }
}
