//! Shared AV-prefix KV cache: a radix trie of frozen, refcounted prefix
//! entries over the [`super::BlockPool`].
//!
//! FastAV's deployed *positional* global pruning makes the post-prune AV
//! prefix KV query-independent: the keep rule depends only on token
//! positions and layout, never on the question. Every request over the
//! same sample/layout/pruning-config therefore produces bit-identical
//! front-layer K/V for the audio-visual prefix — by far the largest token
//! block an AV-LLM ingests — so the serving stack computes it once and
//! shares it.
//!
//! Keying: entries are grouped by a *config key* (global-pruning config +
//! split depth + layout + model fingerprint) and, within a config, stored
//! in a token **trie** keyed by the tokenized prefix. Lookup walks the
//! request's prefix tokens and returns the deepest entry on the path
//! (longest-prefix match), which lets a request resume mid-sequence from
//! any covered prefix length.
//!
//! Lifetime: a hit takes a [`PrefixLease`] (RAII) that pins the entry
//! against eviction while a generation uses it; eviction is LRU over
//! lease-free entries under a configurable byte budget. Entry payloads
//! are [`LayerCache`]s whose blocks live in the shared pool, so "evicted"
//! blocks are only recycled once the last borrowing request drops them —
//! no use-after-free by construction (property-tested in
//! `rust/tests/test_prefix.rs`).
//!
//! Exposure: `GET /v1/pool` reports `stats()`, `POST /v1/cache/flush`
//! calls [`PrefixCache::flush`], and [`PrefixCache::bind_metrics`] keeps
//! the `fastav_prefix_cache_*` counters and `fastav_kv_blocks_*` gauges
//! live in `/metrics`.

use std::collections::{BTreeMap, HashMap};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

use crate::metrics::{Counter, Gauge, Registry};

use super::block::BlockPool;
use super::tier::TieredStore;
use super::LayerCache;

// ------------------------------------------------------------- hashing

const FNV_OFFSET: u64 = 0xcbf2_9ce4_8422_2325;
const FNV_PRIME: u64 = 0x0000_0100_0000_01b3;

/// FNV-1a over raw bytes — the one hash primitive behind
/// [`hash_tokens`]/[`hash_mix`] and the policy layer's spec hashing
/// ([`crate::policy::PruningSpec::spec_hash`]), so the constants can
/// never drift between the cache keys and the spec identities that
/// share the `/v1/pool` accounting namespace.
pub fn hash_bytes(bytes: &[u8]) -> u64 {
    let mut h = FNV_OFFSET;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(FNV_PRIME);
    }
    h
}

/// FNV-1a over a `u32` stream (deterministic across runs/platforms, so
/// cache keys are stable and loggable).
pub fn hash_tokens(seed: u64, tokens: &[u32]) -> u64 {
    let mut h = FNV_OFFSET ^ seed;
    for &t in tokens {
        for b in t.to_le_bytes() {
            h ^= b as u64;
            h = h.wrapping_mul(FNV_PRIME);
        }
    }
    h
}

/// Combine already-hashed parts into one key.
pub fn hash_mix(parts: &[u64]) -> u64 {
    let mut h = FNV_OFFSET;
    for &p in parts {
        for b in p.to_le_bytes() {
            h ^= b as u64;
            h = h.wrapping_mul(FNV_PRIME);
        }
    }
    h
}

// ---------------------------------------------------------------- trie

#[derive(Default)]
struct TrieNode {
    children: HashMap<u32, usize>,
    /// Full entry key when a cached prefix ends at this node.
    key: Option<u64>,
}

/// Token radix trie for one config key. Nodes are arena-allocated with a
/// free list: removal prunes every node made childless and key-less back
/// up the path and recycles the slots, so the arena occupancy is bounded
/// by the *live* entries' path lengths — not by every prefix ever
/// inserted (`prefix_cache_bytes` eviction really frees the index too;
/// regression-tested below).
#[derive(Default)]
struct Trie {
    nodes: Vec<TrieNode>,
    /// Recycled node slots awaiting reuse (never the root).
    free: Vec<usize>,
}

impl Trie {
    fn new() -> Trie {
        Trie { nodes: vec![TrieNode::default()], free: Vec::new() }
    }

    /// Arena slots currently reachable (root included).
    fn live_nodes(&self) -> usize {
        self.nodes.len() - self.free.len()
    }

    fn alloc_node(&mut self) -> usize {
        match self.free.pop() {
            Some(id) => {
                self.nodes[id] = TrieNode::default();
                id
            }
            None => {
                self.nodes.push(TrieNode::default());
                self.nodes.len() - 1
            }
        }
    }

    fn insert(&mut self, tokens: &[u32], key: u64) {
        let mut at = 0;
        for &t in tokens {
            at = match self.nodes[at].children.get(&t) {
                Some(&n) => n,
                None => {
                    let n = self.alloc_node();
                    self.nodes[at].children.insert(t, n);
                    n
                }
            };
        }
        self.nodes[at].key = Some(key);
    }

    /// Deepest entry key along the path of `tokens` (longest-prefix match).
    fn longest(&self, tokens: &[u32]) -> Option<u64> {
        let mut at = 0;
        let mut best = self.nodes[0].key;
        for &t in tokens {
            match self.nodes[at].children.get(&t) {
                Some(&n) => {
                    at = n;
                    if self.nodes[at].key.is_some() {
                        best = self.nodes[at].key;
                    }
                }
                None => break,
            }
        }
        best
    }

    fn remove(&mut self, tokens: &[u32]) {
        // Walk down recording the path so pruning can walk back up.
        let mut path: Vec<(usize, u32)> = Vec::with_capacity(tokens.len());
        let mut at = 0;
        for &t in tokens {
            match self.nodes[at].children.get(&t) {
                Some(&n) => {
                    path.push((at, t));
                    at = n;
                }
                None => return,
            }
        }
        self.nodes[at].key = None;
        // Prune childless, key-less nodes bottom-up and recycle them.
        let mut cur = at;
        while let Some((parent, tok)) = path.pop() {
            if self.nodes[cur].key.is_some() || !self.nodes[cur].children.is_empty() {
                break;
            }
            self.nodes[parent].children.remove(&tok);
            self.nodes[cur] = TrieNode::default();
            self.free.push(cur);
            cur = parent;
        }
    }
}

// --------------------------------------------------------------- entry

/// One frozen AV-prefix: everything `ModelEngine::begin_generation` needs
/// to resume a covered request mid-sequence.
pub struct PrefixEntry {
    /// Tokens covered (`prompt[..prefix_len]`).
    pub prefix_len: usize,
    /// Per front layer (`0..g`): K/V rows for **all** prefix positions —
    /// what the resumed text suffix attends to (global pruning removes
    /// tokens *at* the split layer, so layers below it saw every token).
    pub full_layers: Vec<LayerCache>,
    /// Per front layer: K/V rows for keep∩prefix only — the rows a
    /// generation's own decode-path front caches start from.
    pub keep_layers: Vec<LayerCache>,
    /// Hidden rows after the front half for keep∩prefix, `[rows, d_model]`.
    pub h_keep: Vec<f32>,
    /// Original positions of the keep∩prefix rows (ascending).
    pub keep_positions: Vec<i32>,
    /// Payload bytes (block payloads counted once + hidden rows).
    pub bytes: usize,
}

impl PrefixEntry {
    /// Fill in `bytes` from the payloads.
    pub fn finalize(mut self) -> PrefixEntry {
        let layer_bytes: usize = self
            .full_layers
            .iter()
            .chain(self.keep_layers.iter())
            .map(|c| c.bytes())
            .sum();
        self.bytes = layer_bytes + self.h_keep.len() * std::mem::size_of::<f32>();
        self
    }
}

struct Slot {
    entry: Arc<PrefixEntry>,
    tokens: Vec<u32>,
    cfg: u64,
    /// Outstanding leases (pins against eviction).
    active: usize,
    last_used: u64,
}

/// Bound on the per-config hit/miss counter map: config keys are
/// unbounded across a server's lifetime (every distinct pruning spec ×
/// layout makes one), so the map resets when it would exceed this —
/// accounting degrades to fresh counters, never unbounded memory.
const PER_CFG_CAP: usize = 512;

#[derive(Default)]
struct Inner {
    tries: HashMap<u64, Trie>,
    slots: HashMap<u64, Slot>,
    bytes: usize,
    tick: u64,
    /// Per pruning-config `(hits, misses)` — the mixed-profile
    /// observability split of the aggregate counters.
    per_cfg: HashMap<u64, (u64, u64)>,
}

impl Inner {
    fn count_cfg(&mut self, cfg: u64, hit: bool) {
        if !self.per_cfg.contains_key(&cfg) && self.per_cfg.len() >= PER_CFG_CAP {
            self.per_cfg.clear();
        }
        let e = self.per_cfg.entry(cfg).or_insert((0, 0));
        if hit {
            e.0 += 1;
        } else {
            e.1 += 1;
        }
    }
}

/// Counter/gauge handles bound by [`PrefixCache::bind_metrics`].
struct MetricSinks {
    hits: Arc<Counter>,
    misses: Arc<Counter>,
    evictions: Arc<Counter>,
    entries_g: Arc<Gauge>,
    bytes_g: Arc<Gauge>,
    blocks_used: Arc<Gauge>,
    blocks_shared: Arc<Gauge>,
    blocks_free: Arc<Gauge>,
}

/// Per-pruning-config slice of the cache accounting: entries/bytes/trie
/// occupancy of one config's trie plus that config's own hit/miss
/// counters. Mixed-profile pools report one row per config hash in
/// `GET /v1/pool` instead of a profile-blind aggregate.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct PerConfigPrefixStats {
    /// The cache config key (pruning-config/layout/model hash).
    pub config: u64,
    pub entries: usize,
    pub bytes: usize,
    pub trie_nodes: usize,
    pub hits: u64,
    pub misses: u64,
}

/// Point-in-time cache accounting (the `/v1/pool` payload).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct PrefixCacheStats {
    pub entries: usize,
    pub bytes: usize,
    pub active_leases: usize,
    /// Live trie-arena nodes across all config tries (index overhead;
    /// bounded by live entries' path lengths — see [`Trie`]).
    pub trie_nodes: usize,
    pub hits: u64,
    pub misses: u64,
    pub evictions: u64,
    pub insertions: u64,
}

/// Process-wide prefix cache. Thread-safe (`&self` everywhere); shared
/// across replica threads behind an `Arc`.
pub struct PrefixCache {
    inner: Mutex<Inner>,
    pool: BlockPool,
    /// Eviction budget over entry payload bytes; `usize::MAX` = unlimited.
    budget_bytes: usize,
    hits: AtomicU64,
    misses: AtomicU64,
    evictions: AtomicU64,
    insertions: AtomicU64,
    sinks: Mutex<Option<MetricSinks>>,
    /// Optional spill store: budget evictions demote into it instead of
    /// dropping, and exact-lookup misses promote out of it (see
    /// [`super::tier::TieredStore`] and `docs/TIERED_KV.md`).
    tier: Mutex<Option<Arc<TieredStore>>>,
}

impl PrefixCache {
    /// `budget_bytes == 0` means unlimited (flush/eviction still work).
    pub fn new(budget_bytes: usize) -> PrefixCache {
        Self::new_in(BlockPool::global(), budget_bytes)
    }

    pub fn new_in(pool: BlockPool, budget_bytes: usize) -> PrefixCache {
        PrefixCache {
            inner: Mutex::new(Inner::default()),
            pool,
            budget_bytes: if budget_bytes == 0 { usize::MAX } else { budget_bytes },
            hits: AtomicU64::new(0),
            misses: AtomicU64::new(0),
            evictions: AtomicU64::new(0),
            insertions: AtomicU64::new(0),
            sinks: Mutex::new(None),
            tier: Mutex::new(None),
        }
    }

    /// Attach the spill store this cache demotes into on eviction and
    /// promotes from on an exact-lookup device miss. At most one;
    /// attaching again replaces it. Without a tier, eviction drops
    /// entries exactly as before.
    pub fn attach_tier(&self, tier: Arc<TieredStore>) {
        *self.tier.lock().unwrap() = Some(tier);
    }

    /// The attached spill store, if any.
    pub fn tier(&self) -> Option<Arc<TieredStore>> {
        self.tier.lock().unwrap().clone()
    }

    /// The block pool entry payloads must allocate from.
    pub fn pool(&self) -> &BlockPool {
        &self.pool
    }

    pub fn budget_bytes(&self) -> usize {
        self.budget_bytes
    }

    /// Bind the `fastav_prefix_cache_*` / `fastav_kv_blocks_*` series so
    /// every cache operation keeps `/metrics` current.
    pub fn bind_metrics(&self, metrics: &Registry) {
        *self.sinks.lock().unwrap() = Some(MetricSinks {
            hits: metrics.counter("fastav_prefix_cache_hits_total"),
            misses: metrics.counter("fastav_prefix_cache_misses_total"),
            evictions: metrics.counter("fastav_prefix_cache_evictions_total"),
            entries_g: metrics.gauge("fastav_prefix_cache_entries"),
            bytes_g: metrics.gauge("fastav_prefix_cache_bytes"),
            blocks_used: metrics.gauge("fastav_kv_blocks_used"),
            blocks_shared: metrics.gauge("fastav_kv_blocks_shared"),
            blocks_free: metrics.gauge("fastav_kv_blocks_free"),
        });
        self.refresh_gauges();
    }

    /// Re-export the entry/byte gauges and the pool's `kv_blocks_*`
    /// gauges. Called by cache operations and periodically by replica
    /// threads (block usage also drifts with ordinary appends/compacts).
    pub fn refresh_gauges(&self) {
        let sinks = self.sinks.lock().unwrap();
        if let Some(s) = sinks.as_ref() {
            let (entries, bytes) = {
                let inner = self.inner.lock().unwrap();
                (inner.slots.len(), inner.bytes)
            };
            s.entries_g.set(entries as u64);
            s.bytes_g.set(bytes as u64);
            let ps = self.pool.stats();
            s.blocks_used.set(ps.used as u64);
            s.blocks_shared.set(ps.shared as u64);
            s.blocks_free.set(ps.free as u64);
        }
    }

    fn count_hit(&self) {
        self.hits.fetch_add(1, Ordering::Relaxed);
        if let Some(s) = self.sinks.lock().unwrap().as_ref() {
            s.hits.inc();
        }
    }

    fn count_miss(&self) {
        self.misses.fetch_add(1, Ordering::Relaxed);
        if let Some(s) = self.sinks.lock().unwrap().as_ref() {
            s.misses.inc();
        }
    }

    /// Longest-prefix lookup; a hit pins the entry with a lease. Counts a
    /// hit or a miss.
    pub fn lookup(self: &Arc<Self>, cfg: u64, tokens: &[u32]) -> Option<PrefixLease> {
        self.lookup_longest(cfg, tokens)
    }

    /// Exact-prefix lookup: a hit only when an entry covers *precisely*
    /// `tokens`. The engine resumes only from exact entries (budget-
    /// matched keep rules select over the whole AV set), and counting
    /// hits here — not on partial matches that fall back to full
    /// prefill — keeps the hit/miss counters honest for operators.
    pub fn lookup_exact(self: &Arc<Self>, cfg: u64, tokens: &[u32]) -> Option<PrefixLease> {
        self.lookup_exact_where(cfg, tokens, |_| true)
    }

    /// [`Self::lookup_exact`] gated on a caller predicate evaluated
    /// *before* the hit is counted or a lease taken: an entry the
    /// predicate rejects (e.g. a keep-set mismatch in the engine's
    /// resume path) counts as a **miss**, because nothing is reused.
    /// This is what keeps `fastav_prefix_cache_hits_total` honest for
    /// keep-mismatched lookups (regression-tested below).
    pub fn lookup_exact_where(
        self: &Arc<Self>,
        cfg: u64,
        tokens: &[u32],
        pred: impl FnOnce(&PrefixEntry) -> bool,
    ) -> Option<PrefixLease> {
        let seg_t0 = crate::trace::seg_begin();
        let exact_key = hash_mix(&[cfg, hash_tokens(0, tokens)]);
        // Device probe. The slot is pinned *provisionally* (active += 1)
        // so eviction cannot race the predicate below; a rejection
        // releases the pin before the miss is counted.
        let mut found = {
            let mut inner = self.inner.lock().unwrap();
            inner.tick += 1;
            let tick = inner.tick;
            inner.slots.get_mut(&exact_key).map(|slot| {
                slot.active += 1;
                slot.last_used = tick;
                Arc::clone(&slot.entry)
            })
        };
        // Device miss: promote from the spill tiers. Deserialization is
        // the paying request's own work — still far cheaper than the
        // full front prefill a true miss costs. The promoted entry is
        // re-adopted device-side pre-pinned, so it cannot be evicted
        // before this request leases it (re-adoption may itself demote
        // colder entries back into the tier).
        if found.is_none() {
            if let Some(tier) = self.tier() {
                if let Some((entry, _hit)) = tier.promote(&self.pool, cfg, tokens) {
                    self.insert_arc(cfg, tokens, Arc::clone(&entry), true);
                    found = Some(entry);
                }
            }
        }
        let accepted = match found {
            Some(entry) => {
                if pred(&entry) {
                    Some(entry)
                } else {
                    // Rejected (e.g. keep-set mismatch): nothing is
                    // reused, so unpin and count a miss.
                    self.release_lease(exact_key);
                    None
                }
            }
            None => None,
        };
        {
            let mut inner = self.inner.lock().unwrap();
            inner.count_cfg(cfg, accepted.is_some());
        }
        let lease = match accepted {
            Some(entry) => {
                self.count_hit();
                Some(PrefixLease { cache: Arc::clone(self), key: exact_key, entry })
            }
            None => {
                self.count_miss();
                None
            }
        };
        crate::trace::seg_end("prefix_lookup", None, seg_t0);
        lease
    }

    fn lookup_longest(self: &Arc<Self>, cfg: u64, tokens: &[u32]) -> Option<PrefixLease> {
        let found = {
            let mut inner = self.inner.lock().unwrap();
            inner.tick += 1;
            let tick = inner.tick;
            let key = inner.tries.get(&cfg).and_then(|t| t.longest(tokens));
            let found = key.and_then(|key| {
                inner.slots.get_mut(&key).map(|slot| {
                    slot.active += 1;
                    slot.last_used = tick;
                    (key, Arc::clone(&slot.entry))
                })
            });
            inner.count_cfg(cfg, found.is_some());
            found
        };
        match found {
            Some((key, entry)) => {
                self.count_hit();
                Some(PrefixLease { cache: Arc::clone(self), key, entry })
            }
            None => {
                self.count_miss();
                None
            }
        }
    }

    /// Exact-prefix probe without lease or hit/miss accounting —
    /// admission uses it to split a request's estimate into shared vs
    /// unique bytes, so it must mirror [`Self::lookup_exact`] (a
    /// partial-coverage entry would credit sharing the resume never
    /// uses). Returns `(entry key, entry bytes)`.
    pub fn peek(&self, cfg: u64, tokens: &[u32]) -> Option<(u64, usize)> {
        let key = hash_mix(&[cfg, hash_tokens(0, tokens)]);
        let device = {
            let inner = self.inner.lock().unwrap();
            inner.slots.get(&key).map(|s| (key, s.entry.bytes))
        };
        // Tier-resident entries count as shared too — the resume path
        // will promote them instead of recomputing. Index lookup only:
        // no deserialization or file I/O on the admission path.
        device.or_else(|| {
            self.tier().and_then(|t| t.peek(cfg, tokens)).map(|bytes| (key, bytes))
        })
    }

    /// Insert a frozen entry for `tokens` under `cfg`; no-op if an entry
    /// for the exact prefix already exists (first writer wins — payloads
    /// are deterministic, so both are identical). Evicts LRU lease-free
    /// entries afterwards if the byte budget is exceeded; with a tier
    /// attached, the evicted entries are **demoted** (staged for the
    /// background pruner) instead of dropped.
    pub fn insert(&self, cfg: u64, tokens: &[u32], entry: PrefixEntry) -> bool {
        self.insert_arc(cfg, tokens, Arc::new(entry), false)
    }

    /// [`Self::insert`] over an already-shared entry. The tier promotion
    /// path re-adopts a promoted `Arc` without copying the payload;
    /// `pinned` makes the new slot (or, on a lost insert race, the
    /// concurrent winner's slot) carry one active lease already, so
    /// eviction cannot drop the entry before the promoting request
    /// leases it — the caller owns the matching [`Self::release_lease`]
    /// via the `PrefixLease` it constructs (or releases directly on a
    /// predicate rejection).
    fn insert_arc(&self, cfg: u64, tokens: &[u32], entry: Arc<PrefixEntry>, pinned: bool) -> bool {
        debug_assert!(
            entry
                .full_layers
                .iter()
                .chain(entry.keep_layers.iter())
                .all(|c| c.pool().same_pool(&self.pool)),
            "entry blocks must come from the cache's pool"
        );
        let (inserted, victims) = {
            let mut inner = self.inner.lock().unwrap();
            let key = hash_mix(&[cfg, hash_tokens(0, tokens)]);
            inner.tick += 1;
            let tick = inner.tick;
            if let Some(slot) = inner.slots.get_mut(&key) {
                if pinned {
                    slot.active += 1;
                    slot.last_used = tick;
                }
                (false, Vec::new())
            } else {
                inner.bytes += entry.bytes;
                inner.slots.insert(
                    key,
                    Slot {
                        entry,
                        tokens: tokens.to_vec(),
                        cfg,
                        active: usize::from(pinned),
                        last_used: tick,
                    },
                );
                inner.tries.entry(cfg).or_insert_with(Trie::new).insert(tokens, key);
                let victims =
                    Self::evict_over_budget(&mut inner, self.budget_bytes, &self.evictions);
                (true, victims)
            }
        };
        // Demotion staging happens *after* the inner lock is released:
        // an O(1) Arc move into the tier's pending queue — the pruner
        // thread does the serialization and spill I/O later.
        if !victims.is_empty() {
            if let Some(tier) = self.tier() {
                for (vcfg, vtokens, ventry) in victims {
                    tier.stage_demotion(vcfg, vtokens, ventry);
                }
            }
        }
        if inserted {
            self.insertions.fetch_add(1, Ordering::Relaxed);
        }
        self.refresh_gauges();
        if inserted {
            if let Some(s) = self.sinks.lock().unwrap().as_ref() {
                // Evictions triggered by this insert are already in the
                // atomic; mirror the delta into the counter series.
                let total = self.evictions.load(Ordering::Relaxed);
                let exported = s.evictions.get();
                if total > exported {
                    s.evictions.add(total - exported);
                }
            }
        }
        inserted
    }

    /// Evict LRU lease-free entries until the budget holds, returning
    /// the victims so the caller can demote them into the tier (with no
    /// tier attached they are simply dropped, the pre-tier behavior).
    fn evict_over_budget(
        inner: &mut Inner,
        budget: usize,
        evictions: &AtomicU64,
    ) -> Vec<(u64, Vec<u32>, Arc<PrefixEntry>)> {
        let mut victims = Vec::new();
        while inner.bytes > budget {
            let victim = inner
                .slots
                .iter()
                .filter(|(_, s)| s.active == 0)
                .min_by_key(|(_, s)| s.last_used)
                .map(|(&k, _)| k);
            let Some(key) = victim else { break };
            if let Some(v) = Self::evict_key(inner, key) {
                victims.push(v);
            }
            evictions.fetch_add(1, Ordering::Relaxed);
        }
        victims
    }

    fn evict_key(inner: &mut Inner, key: u64) -> Option<(u64, Vec<u32>, Arc<PrefixEntry>)> {
        let slot = inner.slots.remove(&key)?;
        inner.bytes = inner.bytes.saturating_sub(slot.entry.bytes);
        if let Some(trie) = inner.tries.get_mut(&slot.cfg) {
            trie.remove(&slot.tokens);
            // Drop the whole per-config trie once its last entry is
            // gone (only the root remains) — config keys are
            // unbounded across a server's lifetime.
            if trie.nodes[0].children.is_empty() {
                inner.tries.remove(&slot.cfg);
            }
        }
        // Returning the Arc keeps the blocks alive for demotion; when
        // the caller drops it instead, the blocks are recycled once the
        // last in-flight borrower (cloned LayerCache / outstanding
        // lease upgrade) lets go — never before.
        Some((slot.cfg, slot.tokens, slot.entry))
    }

    /// Drop every lease-free entry (the `POST /v1/cache/flush` endpoint).
    /// Returns `(entries_evicted, bytes_freed)`. Flush *drops* — it
    /// never demotes into the tier (the pool-level flush drains the
    /// tiers in the same call; see `ReplicaPool::flush_prefix_cache`).
    pub fn flush(&self) -> (usize, usize) {
        let (n, freed) = {
            let mut inner = self.inner.lock().unwrap();
            let victims: Vec<u64> = inner
                .slots
                .iter()
                .filter(|(_, s)| s.active == 0)
                .map(|(&k, _)| k)
                .collect();
            let before = inner.bytes;
            for key in &victims {
                drop(Self::evict_key(&mut inner, *key));
            }
            (victims.len(), before - inner.bytes)
        };
        self.evictions.fetch_add(n as u64, Ordering::Relaxed);
        if let Some(s) = self.sinks.lock().unwrap().as_ref() {
            s.evictions.add(n as u64);
        }
        self.refresh_gauges();
        (n, freed)
    }

    fn release_lease(&self, key: u64) {
        let mut inner = self.inner.lock().unwrap();
        if let Some(slot) = inner.slots.get_mut(&key) {
            slot.active = slot.active.saturating_sub(1);
        }
    }

    /// Per-config accounting rows, sorted by config key. A config
    /// appears when it has live entries, live trie nodes, or recorded
    /// hit/miss traffic (counters survive eviction of the entries, up
    /// to the [`PER_CFG_CAP`] reset).
    pub fn per_config_stats(&self) -> Vec<PerConfigPrefixStats> {
        fn row(
            map: &mut BTreeMap<u64, PerConfigPrefixStats>,
            cfg: u64,
        ) -> &mut PerConfigPrefixStats {
            map.entry(cfg)
                .or_insert_with(|| PerConfigPrefixStats { config: cfg, ..Default::default() })
        }
        let inner = self.inner.lock().unwrap();
        let mut map: BTreeMap<u64, PerConfigPrefixStats> = BTreeMap::new();
        for slot in inner.slots.values() {
            let e = row(&mut map, slot.cfg);
            e.entries += 1;
            e.bytes += slot.entry.bytes;
        }
        for (&cfg, trie) in &inner.tries {
            row(&mut map, cfg).trie_nodes = trie.live_nodes();
        }
        for (&cfg, &(hits, misses)) in &inner.per_cfg {
            let e = row(&mut map, cfg);
            e.hits = hits;
            e.misses = misses;
        }
        map.into_values().collect()
    }

    pub fn stats(&self) -> PrefixCacheStats {
        let (entries, bytes, active, trie_nodes) = {
            let inner = self.inner.lock().unwrap();
            (
                inner.slots.len(),
                inner.bytes,
                inner.slots.values().map(|s| s.active).sum(),
                inner.tries.values().map(|t| t.live_nodes()).sum(),
            )
        };
        PrefixCacheStats {
            entries,
            bytes,
            active_leases: active,
            trie_nodes,
            hits: self.hits.load(Ordering::Relaxed),
            misses: self.misses.load(Ordering::Relaxed),
            evictions: self.evictions.load(Ordering::Relaxed),
            insertions: self.insertions.load(Ordering::Relaxed),
        }
    }
}

/// RAII pin on a cache entry: holds the payload `Arc` and decrements the
/// entry's active count (making it evictable again) on drop.
pub struct PrefixLease {
    cache: Arc<PrefixCache>,
    key: u64,
    entry: Arc<PrefixEntry>,
}

impl PrefixLease {
    pub fn entry(&self) -> &PrefixEntry {
        &self.entry
    }

    pub fn key(&self) -> u64 {
        self.key
    }
}

impl Drop for PrefixLease {
    fn drop(&mut self) {
        self.cache.release_lease(self.key);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn entry_with(pool: &BlockPool, rows: usize) -> PrefixEntry {
        let mut c = LayerCache::new_in(pool.clone(), 1, 2, rows.max(1));
        for i in 0..rows {
            c.append(&[i as f32, 0.0], &[0.0, i as f32], i as i32);
        }
        PrefixEntry {
            prefix_len: rows,
            full_layers: vec![c.clone()],
            keep_layers: vec![c],
            h_keep: vec![0.5; rows],
            keep_positions: (0..rows as i32).collect(),
            bytes: 0,
        }
        .finalize()
    }

    #[test]
    fn longest_prefix_match() {
        let pool = BlockPool::new();
        let cache = Arc::new(PrefixCache::new_in(pool.clone(), 0));
        let cfg = 7;
        assert!(cache.insert(cfg, &[1, 2, 3], entry_with(&pool, 3)));
        assert!(cache.insert(cfg, &[1, 2, 3, 4, 5], entry_with(&pool, 5)));
        // Exact longer prefix wins.
        let lease = cache.lookup(cfg, &[1, 2, 3, 4, 5, 99]).unwrap();
        assert_eq!(lease.entry().prefix_len, 5);
        // Shorter coverage still matches.
        let lease2 = cache.lookup(cfg, &[1, 2, 3, 8]).unwrap();
        assert_eq!(lease2.entry().prefix_len, 3);
        // Different config sees nothing.
        assert!(cache.lookup(8, &[1, 2, 3]).is_none());
        let s = cache.stats();
        assert_eq!((s.hits, s.misses, s.entries), (2, 1, 2));
    }

    #[test]
    fn exact_lookup_rejects_partial_coverage() {
        let pool = BlockPool::new();
        let cache = Arc::new(PrefixCache::new_in(pool.clone(), 0));
        cache.insert(1, &[1, 2], entry_with(&pool, 2));
        // Longest-match sees the shorter entry; exact does not — and the
        // exact miss is counted as a miss, not a hit.
        assert!(cache.lookup(1, &[1, 2, 3]).is_some());
        assert!(cache.lookup_exact(1, &[1, 2, 3]).is_none());
        assert!(cache.lookup_exact(1, &[1, 2]).is_some());
        let s = cache.stats();
        assert_eq!((s.hits, s.misses), (2, 1));
    }

    #[test]
    fn duplicate_insert_is_noop() {
        let pool = BlockPool::new();
        let cache = Arc::new(PrefixCache::new_in(pool.clone(), 0));
        assert!(cache.insert(1, &[5, 6], entry_with(&pool, 2)));
        assert!(!cache.insert(1, &[5, 6], entry_with(&pool, 2)));
        assert_eq!(cache.stats().entries, 1);
    }

    #[test]
    fn lru_eviction_skips_leased_entries() {
        let pool = BlockPool::new();
        let per_entry = entry_with(&pool, 2).bytes;
        // Budget fits exactly two entries.
        let cache = Arc::new(PrefixCache::new_in(pool.clone(), 2 * per_entry));
        cache.insert(1, &[1], entry_with(&pool, 2));
        cache.insert(1, &[2], entry_with(&pool, 2));
        // Pin [1]; touch nothing else, then overflow.
        let lease = cache.lookup(1, &[1]).unwrap();
        cache.insert(1, &[3], entry_with(&pool, 2));
        let s = cache.stats();
        assert_eq!(s.evictions, 1);
        // [2] (LRU among lease-free) was evicted; [1] survived its pin.
        assert!(cache.lookup(1, &[2, 9]).is_none());
        assert!(cache.lookup(1, &[1, 9]).is_some());
        drop(lease);
        let (flushed, freed) = cache.flush();
        assert_eq!(flushed, 2);
        assert!(freed > 0);
        assert_eq!(cache.stats().entries, 0);
    }

    #[test]
    fn eviction_reclaims_trie_arena() {
        // Regression: trie nodes used to leak forever (~path-length nodes
        // per distinct prefix, uncapped by the byte budget). Eviction must
        // return the arena occupancy to a bound set by the *live* entries.
        let pool = BlockPool::new();
        let per_entry = entry_with(&pool, 2).bytes;
        let cache = Arc::new(PrefixCache::new_in(pool.clone(), 2 * per_entry));
        let prefix_len = 40;
        for i in 0..50u32 {
            let tokens: Vec<u32> = (0..prefix_len).map(|j| i * 1000 + j).collect();
            cache.insert(1, &tokens, entry_with(&pool, 2));
        }
        let s = cache.stats();
        assert_eq!(s.entries, 2, "budget keeps two entries");
        assert!(s.evictions >= 48);
        // Bound: root + one path per live entry (paths may share nothing).
        let bound = 1 + s.entries * prefix_len as usize;
        assert!(
            s.trie_nodes <= bound,
            "trie arena leaked: {} live nodes > bound {}",
            s.trie_nodes,
            bound
        );
        // Flushing the rest drops the per-config trie entirely.
        cache.flush();
        assert_eq!(cache.stats().trie_nodes, 0, "empty trie must be dropped");
        // Re-inserting after a flush still works (slots recycled).
        assert!(cache.insert(1, &[1, 2, 3], entry_with(&pool, 2)));
        assert!(cache.lookup(1, &[1, 2, 3]).is_some());
    }

    #[test]
    fn branching_removal_keeps_shared_spine() {
        // Removing one branch must not free nodes another entry's path
        // still uses, and must not break lookups through the shared spine.
        let pool = BlockPool::new();
        let cache = Arc::new(PrefixCache::new_in(pool.clone(), 0));
        cache.insert(1, &[1, 2, 3, 4], entry_with(&pool, 2));
        cache.insert(1, &[1, 2, 9], entry_with(&pool, 2));
        let before = cache.stats().trie_nodes; // root + 1,2 + {3,4} + {9}
        assert_eq!(before, 1 + 2 + 2 + 1);
        // Pin [1,2,9]; flush evicts only the lease-free [1,2,3,4].
        let lease = cache.lookup(1, &[1, 2, 9]).unwrap();
        let (evicted, _) = cache.flush();
        assert_eq!(evicted, 1);
        let s = cache.stats();
        assert_eq!(s.trie_nodes, 1 + 2 + 1, "only the 3,4 branch freed");
        assert!(cache.lookup(1, &[1, 2, 9, 7]).is_some(), "shared spine intact");
        drop(lease);
        assert!(cache.lookup(1, &[1, 2, 3, 4, 5]).is_none());
    }

    #[test]
    fn exact_where_counts_rejected_entry_as_miss() {
        // Regression: the engine's keep-set check used to run *after* a
        // counted lookup_exact hit, inflating hits_total on lookups that
        // reused nothing. The predicate-gated lookup counts those as
        // misses and takes no lease.
        let pool = BlockPool::new();
        let cache = Arc::new(PrefixCache::new_in(pool.clone(), 0));
        cache.insert(1, &[1, 2], entry_with(&pool, 2));
        // Predicate rejects (keep-set mismatch): miss, no lease pinned.
        assert!(cache.lookup_exact_where(1, &[1, 2], |_| false).is_none());
        let s = cache.stats();
        assert_eq!((s.hits, s.misses), (0, 1), "rejected entry must count as a miss");
        assert_eq!(s.active_leases, 0, "no lease on a rejected entry");
        // Predicate accepts: ordinary hit with a lease.
        let lease = cache.lookup_exact_where(1, &[1, 2], |e| e.prefix_len == 2);
        assert!(lease.is_some());
        let s = cache.stats();
        assert_eq!((s.hits, s.misses), (1, 1));
        assert_eq!(s.active_leases, 1);
    }

    #[test]
    fn per_config_stats_split_mixed_configs() {
        // Two pruning configs sharing one cache: the aggregate counters
        // conflate them, the per-config rows must not.
        let pool = BlockPool::new();
        let cache = Arc::new(PrefixCache::new_in(pool.clone(), 0));
        cache.insert(10, &[1, 2], entry_with(&pool, 2));
        cache.insert(10, &[3, 4], entry_with(&pool, 2));
        cache.insert(20, &[1, 2], entry_with(&pool, 2));
        assert!(cache.lookup_exact(10, &[1, 2]).is_some()); // cfg 10 hit
        assert!(cache.lookup_exact(10, &[9, 9]).is_none()); // cfg 10 miss
        assert!(cache.lookup_exact(20, &[1, 2]).is_some()); // cfg 20 hit
        assert!(cache.lookup_exact(30, &[1, 2]).is_none()); // cfg 30 miss only
        let per = cache.per_config_stats();
        assert_eq!(per.len(), 3);
        let get = |cfg: u64| *per.iter().find(|r| r.config == cfg).unwrap();
        let c10 = get(10);
        assert_eq!((c10.entries, c10.hits, c10.misses), (2, 1, 1));
        assert!(c10.bytes > 0 && c10.trie_nodes > 0);
        let c20 = get(20);
        assert_eq!((c20.entries, c20.hits, c20.misses), (1, 1, 0));
        let c30 = get(30);
        assert_eq!((c30.entries, c30.hits, c30.misses), (0, 0, 1));
        // The per-config rows sum to the aggregate counters.
        let s = cache.stats();
        assert_eq!(per.iter().map(|r| r.hits).sum::<u64>(), s.hits);
        assert_eq!(per.iter().map(|r| r.misses).sum::<u64>(), s.misses);
        assert_eq!(per.iter().map(|r| r.entries).sum::<usize>(), s.entries);
        assert_eq!(per.iter().map(|r| r.bytes).sum::<usize>(), s.bytes);
        // Eviction clears a config's entries but keeps its traffic row.
        cache.flush();
        let per = cache.per_config_stats();
        let c10 = *per.iter().find(|r| r.config == 10).unwrap();
        assert_eq!((c10.entries, c10.bytes, c10.trie_nodes), (0, 0, 0));
        assert_eq!((c10.hits, c10.misses), (1, 1), "counters survive eviction");
    }

    #[test]
    fn eviction_demotes_into_tier_and_lookup_promotes() {
        use crate::kvcache::tier::{PruneBudget, TierConfig, TieredStore};
        let pool = BlockPool::new();
        let per_entry = entry_with(&pool, 2).bytes;
        // Device budget fits exactly one entry; the tier catches the rest.
        let cache = Arc::new(PrefixCache::new_in(pool.clone(), per_entry));
        let tier =
            Arc::new(TieredStore::new(TierConfig { ram_bytes: 1 << 20, ..Default::default() }));
        cache.attach_tier(Arc::clone(&tier));
        cache.insert(1, &[1], entry_with(&pool, 2));
        cache.insert(1, &[2], entry_with(&pool, 2)); // evicts [1] → staged
        assert_eq!(cache.stats().entries, 1);
        assert_eq!(tier.stats().pending_entries, 1, "eviction demotes, not drops");
        // The admission probe sees the tier-resident entry without
        // promoting it.
        assert!(cache.peek(1, &[1]).is_some());
        assert_eq!(tier.stats().pending_entries, 1);
        // Serialize into the RAM tier, then promote via exact lookup.
        tier.prune_run(PruneBudget::default());
        assert_eq!(tier.stats().ram_entries, 1);
        let lease = cache.lookup_exact(1, &[1]).expect("tier promotion must hit");
        assert_eq!(lease.entry().prefix_len, 2);
        assert_eq!(cache.stats().hits, 1, "promotion counts as a cache hit");
        assert_eq!(tier.stats().promotions_ram, 1);
        // Re-adoption put [1] back on-device (pinned), demoting [2].
        assert_eq!(tier.stats().pending_entries, 1);
        assert!(cache.peek(1, &[2]).is_some(), "demoted [2] still reachable");
        drop(lease);
    }

    #[test]
    fn rejected_promotion_counts_miss_and_readopts_entry() {
        use crate::kvcache::tier::{TierConfig, TieredStore};
        let pool = BlockPool::new();
        let cache = Arc::new(PrefixCache::new_in(pool.clone(), 0));
        let tier =
            Arc::new(TieredStore::new(TierConfig { ram_bytes: 1 << 20, ..Default::default() }));
        cache.attach_tier(Arc::clone(&tier));
        // An entry already demoted (still in the pending queue).
        tier.stage_demotion(1, vec![7], Arc::new(entry_with(&pool, 2)));
        // The predicate rejects the promoted entry: the lookup is a
        // miss, takes no lease — but the entry stays device-side for
        // the next compatible request.
        assert!(cache.lookup_exact_where(1, &[7], |_| false).is_none());
        let s = cache.stats();
        assert_eq!((s.hits, s.misses), (0, 1));
        assert_eq!(s.active_leases, 0, "rejected promotion leaves no pin");
        assert_eq!(s.entries, 1, "promoted entry re-adopted device-side");
        assert!(cache.lookup_exact(1, &[7]).is_some(), "second lookup hits on-device");
        assert_eq!(tier.stats().promotions_ram, 1, "only the first lookup promoted");
    }

    #[test]
    fn evicted_entry_blocks_survive_borrowers() {
        let pool = BlockPool::new();
        let cache = Arc::new(PrefixCache::new_in(pool.clone(), 0));
        cache.insert(1, &[1, 2], entry_with(&pool, 2));
        let lease = cache.lookup(1, &[1, 2]).unwrap();
        // Borrow the payload the way a generation does: clone the cache.
        let borrowed = lease.entry().keep_layers[0].clone();
        drop(lease);
        cache.flush();
        assert_eq!(cache.stats().entries, 0);
        // The borrowed rows are still readable (blocks refcounted).
        assert_eq!(borrowed.k_row(0, 1)[0], 1.0);
        drop(borrowed);
        assert_eq!(pool.stats().used, 0, "blocks recycled after last borrower");
    }
}
