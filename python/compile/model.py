"""L2: the AV-LLM decoder in JAX — every computation the rust runtime executes.

Entry points (AOT-lowered per bucket by ``aot.py``; flat argument lists are
the rust↔artifact ABI, documented per function):

  * :func:`prefill_front`  — fused layers ``0..mid`` over the full prompt.
  * :func:`back_layer`     — one layer ``>= mid`` returning the last-query
    importance scores that drive FastAV's fine pruning.
  * :func:`decode_layer`   — one layer of a single-token decode step over a
    compacted KV cache (fused attention + importance).
  * :func:`decode_layer_batched` — B independent single-token decode steps
    over per-request KV caches in one dispatch (continuous-batching decode).
  * :func:`logits_head`    — final RMSNorm + tied unembedding.
  * :func:`logits_head_batched` — the ``[B, d]`` logits head (one dispatch
    replaces B single-vector logits dispatches in a decode quantum).
  * :func:`calib_probe`    — all-layer rollout + raw-attention stacks
    (offline calibration; Figs. 1–2).

Tensor-parallel (head-sharded) entry points, lowered when
``cfg.tp_degree > 1`` so the rust device-mesh backend can split one
layer across D devices (shard ``s`` owns heads ``[s·H/D, (s+1)·H/D)``;
the host concatenates attention outputs / sums logits partials):

  * :func:`layer_shard` / :func:`layer_tail` — prefill-shaped layer split
    at the attention/combine boundary (front layers and back layers).
  * :func:`decode_shard` / :func:`decode_tail` — the single-token split.
  * :func:`decode_shard_batched` / :func:`decode_tail_batched` — the
    fused-batch split.
  * :func:`logits_shard` / :func:`logits_shard_batched` — vocab logits as
    per-device partial sums over a ``d_model/D`` column slice.

Also hosts the batched training forward (:func:`train_forward`) — pure jnp
(numerically identical to the kernels; see test_kernels.py) so build-time
training is fast on CPU.

Weights are runtime *arguments*, never baked into artifacts: one artifact
serves all layers of all checkpoints with the same shape.
"""

import jax
import jax.numpy as jnp

from .kernels import (
    decode_attention,
    flash_attention,
    importance_scores,
    rollout_step,
    ref,
)

EPS = 1e-5


# ------------------------------------------------------------ building blocks


def rms_norm(x, scale):
    """RMSNorm over the last axis (scale-only, LLaMA-style)."""
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    return x * jax.lax.rsqrt(var + EPS) * scale


def rope_angles(positions, d_head, theta):
    """Rotation angles ``[n, d_head/2]`` for explicit integer positions.

    Positions are *original* sequence positions — compaction after pruning
    re-indexes rows but keeps these phases, which is what makes pruned and
    masked execution equivalent (integration-tested on the rust side).
    """
    half = d_head // 2
    freqs = theta ** (-jnp.arange(half, dtype=jnp.float32) * 2.0 / d_head)
    return positions.astype(jnp.float32)[..., None] * freqs


def apply_rope(x, angles):
    """Rotate feature pairs of ``x [..., n, H, dh]`` by ``angles [..., n, half]``."""
    half = x.shape[-1] // 2
    x1 = x[..., :half]
    x2 = x[..., half:]
    # angles: [..., n, half] -> insert a heads axis before the last dim.
    cos = jnp.cos(angles)[..., None, :]
    sin = jnp.sin(angles)[..., None, :]
    return jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)


def qkv_project(x, wq, wk, wv, n_heads, d_head, angles):
    """Project hidden states to per-head Q/K/V with RoPE applied to Q and K.

    Args:
      x: ``[n, d]`` normalized hidden states.
      angles: ``[n, d_head/2]`` RoPE angles for these rows.

    Returns:
      q, k, v each ``[H, n, dh]``.
    """
    n = x.shape[0]

    def heads(w):
        return (x @ w).reshape(n, n_heads, d_head)

    q = apply_rope(heads(wq), angles)
    k = apply_rope(heads(wk), angles)
    v = heads(wv)
    return (
        jnp.transpose(q, (1, 0, 2)),
        jnp.transpose(k, (1, 0, 2)),
        jnp.transpose(v, (1, 0, 2)),
    )


def swiglu(x, wg, wu, wd):
    """SwiGLU MLP: ``(silu(x Wg) * (x Wu)) Wd``."""
    return (jax.nn.silu(x @ wg) * (x @ wu)) @ wd


def _attend(q, k, v, mask, use_pallas):
    if use_pallas:
        return flash_attention(q, k, v, mask, causal=True)
    return ref.ref_attention(q, k, v, mask, causal=True)


def layer_fwd(h, mask, angles, p, cfg, use_pallas):
    """One pre-LN transformer block over ``[n, d]`` hidden states.

    ``p`` is the per-layer parameter dict (ln1, wq, wk, wv, wo, ln2, wg,
    wu, wd). Returns (h', k, v, q) with k/v/q in ``[H, n, dh]``.
    """
    x = rms_norm(h, p["ln1"])
    q, k, v = qkv_project(x, p["wq"], p["wk"], p["wv"], cfg.n_heads, cfg.d_head, angles)
    attn = _attend(q, k, v, mask, use_pallas)  # [H, n, dh]
    attn = jnp.transpose(attn, (1, 0, 2)).reshape(h.shape[0], cfg.d_model)
    h = h + (attn * mask[:, None]) @ p["wo"]
    x2 = rms_norm(h, p["ln2"])
    h = h + swiglu(x2, p["wg"], p["wu"], p["wd"]) * mask[:, None]
    return h, k, v, q


LAYER_PARAM_NAMES = ("ln1", "wq", "wk", "wv", "wo", "ln2", "wg", "wu", "wd")


def _layer_dict(args):
    return dict(zip(LAYER_PARAM_NAMES, args))


# ------------------------------------------------------------- AOT entry points


def prefill_front(cfg, use_pallas, x_emb, mask, positions, *stacked):
    """Layers ``0..mid`` fused over the full prompt (one dispatch).

    ABI (all float32 unless noted):
      inputs:  x_emb ``[n, d]``; mask ``[n]``; positions ``[n]`` int32;
               then the 9 per-layer params each stacked ``[mid, ...]`` in
               ``LAYER_PARAM_NAMES`` order.
      outputs: (h ``[n, d]``, k_stack ``[mid, H, n, dh]``,
                v_stack ``[mid, H, n, dh]``)
    """
    angles = rope_angles(positions, cfg.d_head, cfg.rope_theta)
    params = _layer_dict(stacked)

    def step(h, layer_params):
        h, k, v, _ = layer_fwd(h, mask, angles, layer_params, cfg, use_pallas)
        return h, (k, v)

    h, (k_stack, v_stack) = jax.lax.scan(step, x_emb, params)
    return h, k_stack, v_stack


def back_layer(cfg, use_pallas, h, mask, positions, last_idx, *layer_params):
    """One post-mid layer during prefill + FastAV importance (paper Eq. 4).

    ABI:
      inputs:  h ``[n, d]``; mask ``[n]``; positions ``[n]`` int32;
               last_idx ``[]`` int32 (row of the final prompt token after
               compaction); 9 single-layer params.
      outputs: (h' ``[n, d]``, k ``[H, n, dh]``, v ``[H, n, dh]``,
                s ``[n]`` importance scores).
    """
    p = _layer_dict(layer_params)
    angles = rope_angles(positions, cfg.d_head, cfg.rope_theta)
    h_out, k, v, q = layer_fwd(h, mask, angles, p, cfg, use_pallas)
    q_last = jax.lax.dynamic_index_in_dim(q, last_idx, axis=1, keepdims=False)  # [H, dh]
    if use_pallas:
        s = importance_scores(q_last, k, mask)
    else:
        s = ref.ref_importance(q_last, k, mask)
    return h_out, k, v, s


def decode_layer(cfg, use_pallas, x, pos, cur_idx, k_cache, v_cache, mask, *layer_params):
    """One layer of a single-token decode step over a compacted cache.

    The current token's K/V are computed here, written into slot
    ``cur_idx`` (the rust coordinator guarantees ``mask[cur_idx] == 1`` and
    that the slot is otherwise unused), and returned so the host cache can
    be updated without re-reading device memory.

    ABI:
      inputs:  x ``[d]``; pos ``[]`` int32 (original position of the new
               token); cur_idx ``[]`` int32 (its cache slot);
               k_cache/v_cache ``[H, n, dh]``; mask ``[n]``; 9 params.
      outputs: (x' ``[d]``, k_new ``[H, dh]``, v_new ``[H, dh]``,
                s ``[n]`` importance row incl. the new token).
    """
    p = _layer_dict(layer_params)
    xi = rms_norm(x, p["ln1"])[None, :]  # [1, d]
    angles = rope_angles(jnp.reshape(pos, (1,)), cfg.d_head, cfg.rope_theta)
    q, k, v = qkv_project(xi, p["wq"], p["wk"], p["wv"], cfg.n_heads, cfg.d_head, angles)
    k_new = k[:, 0, :]
    v_new = v[:, 0, :]
    k_full = jax.lax.dynamic_update_index_in_dim(k_cache, k_new, cur_idx, axis=1)
    v_full = jax.lax.dynamic_update_index_in_dim(v_cache, v_new, cur_idx, axis=1)
    q1 = q[:, 0, :]
    if use_pallas:
        out, s = decode_attention(q1, k_full, v_full, mask)
    else:
        out, s = ref.ref_decode_attention(q1, k_full, v_full, mask)
    x = x + out.reshape(cfg.d_model) @ p["wo"]
    x2 = rms_norm(x, p["ln2"])
    x = x + swiglu(x2, p["wg"], p["wu"], p["wd"])
    return x, k_new, v_new, s


def batched_decode_attention(q, k, v, mask):
    """Single-query attention over a batch of independent caches.

    The decode-time counterpart of :func:`batched_attention` (same key
    masking and softmax guards), specialized to one query row per batch
    element; per-row semantics match ``ref.ref_decode_attention`` exactly
    (including the head-averaged importance row and its validity gating),
    which is what makes the batched artifact token-for-token equivalent to
    B single-token :func:`decode_layer` dispatches.

    Args:
      q: ``[B, H, dh]`` current decode queries.
      k, v: ``[B, H, n, dh]`` per-request caches (query's own K/V already
        scattered in by the caller).
      mask: ``[B, n]`` per-request validity masks; an all-zero row is a
        batch padding slot and yields an all-zero output row.

    Returns:
      ``(out [B, H, dh], s [B, n])``.
    """
    dh = q.shape[-1]
    scale = 1.0 / jnp.sqrt(jnp.float32(dh))
    logits = jnp.einsum("bhd,bhnd->bhn", q, k) * scale
    logits = logits + jnp.where(mask[:, None, :] > 0.5, 0.0, ref.NEG_INF)
    m = jnp.maximum(jnp.max(logits, axis=-1, keepdims=True), ref.NEG_INF / 2)
    p = jnp.exp(logits - m)
    p = p / jnp.maximum(jnp.sum(p, axis=-1, keepdims=True), 1e-30)
    out = jnp.einsum("bhn,bhnd->bhd", p, v)
    return out, jnp.mean(p, axis=1) * mask


def decode_layer_batched(cfg, use_pallas, x, pos, cur_idx, k_cache, v_cache, mask,
                         *layer_params):
    """One layer of B independent single-token decode steps, fused.

    Row ``b`` computes exactly what :func:`decode_layer` computes for that
    request — requests never attend across the batch; batching only
    amortizes dispatch/upload cost. Padding rows (``mask[b] == 0``
    everywhere, ``x[b] == 0``) stay exactly zero through the layer, so a
    partially-filled batch bucket is safe.

    The attention itself is the pure-jnp :func:`batched_decode_attention`
    for both kernel impls (the single-request Pallas decode kernel has no
    batched grid; numerics agree within the tested kernel tolerance).

    ABI:
      inputs:  x ``[B, d]``; pos ``[B]`` int32 (original position of each
               new token); cur_idx ``[B]`` int32 (its cache slot);
               k_cache/v_cache ``[B, H, n, dh]``; mask ``[B, n]``;
               9 single-layer params (shared across the batch).
      outputs: (x' ``[B, d]``, k_new ``[B, H, dh]``, v_new ``[B, H, dh]``,
                s ``[B, n]`` importance rows incl. each new token).
    """
    del use_pallas  # see docstring: jnp attention on both paths
    p = _layer_dict(layer_params)
    xi = rms_norm(x, p["ln1"])  # [B, d]
    angles = rope_angles(pos, cfg.d_head, cfg.rope_theta)  # [B, dh/2]
    # One query row per batch element: qkv_project's sequence axis *is*
    # the batch axis here (rows are independent until attention).
    q, k, v = qkv_project(xi, p["wq"], p["wk"], p["wv"], cfg.n_heads, cfg.d_head, angles)
    k_new = jnp.transpose(k, (1, 0, 2))  # [B, H, dh]
    v_new = jnp.transpose(v, (1, 0, 2))
    q_b = jnp.transpose(q, (1, 0, 2))

    def scatter(cache, row, idx):
        return jax.lax.dynamic_update_index_in_dim(cache, row, idx, axis=1)

    k_full = jax.vmap(scatter)(k_cache, k_new, cur_idx)
    v_full = jax.vmap(scatter)(v_cache, v_new, cur_idx)
    out, s = batched_decode_attention(q_b, k_full, v_full, mask)
    x = x + out.reshape(x.shape[0], cfg.d_model) @ p["wo"]
    x2 = rms_norm(x, p["ln2"])
    x = x + swiglu(x2, p["wg"], p["wu"], p["wd"])
    return x, k_new, v_new, s


def logits_head(cfg, x, ln_f, emb):
    """Final RMSNorm + tied unembedding.

    ABI: inputs x ``[d]``, ln_f ``[d]``, emb ``[vocab, d]``;
         output logits ``[vocab]``.
    """
    return rms_norm(x, ln_f) @ emb.T


# Batched logits head: row ``b`` equals ``logits_head(x[b])`` — the
# computation is shape-polymorphic (rms_norm and the matmul broadcast
# over a leading batch axis), so the batched entry *is* the single-vector
# head lowered at ``[B, d]``. One dispatch replaces the B per-request
# logits dispatches at the end of a fused decode quantum; a batch-padding
# row (``x[b] == 0``) yields an all-zero logits row, which the host
# ignores. ABI: x ``[B, d]``, ln_f ``[d]``, emb ``[vocab, d]`` →
# logits ``[B, vocab]``.
logits_head_batched = logits_head


# ------------------------------------------------- head-sharded (mesh) entries


def _shard_heads(w_shard, d_head):
    """Head count owned by a shard, inferred from its QKV column slice."""
    return w_shard.shape[1] // d_head


def _partial_scale(heads_s, n_heads_total):
    """Rescale a shard-local head *mean* into an all-reduce *partial*.

    The per-head softmax is shard-local, so the full-model head mean
    decomposes into per-shard sums divided by the total head count:
    ``mean_shard · (Hs / H)``. Summing the partials across shards
    reproduces the unsharded row (exactly for the shipped power-of-two
    shard degrees). Reusing the reference kernels + this one scale keeps
    the numerically sensitive softmax guards in exactly one place
    (``kernels/ref.py``).
    """
    return jnp.float32(heads_s) / jnp.float32(n_heads_total)


def layer_shard(cfg, use_pallas, h, mask, positions, last_idx,
                ln1, wq_s, wk_s, wv_s):
    """Per-head-shard half of a prefill-shaped layer (front or back).

    Computes Q/K/V and causal attention for this shard's heads only; the
    residual/MLP half (:func:`layer_tail`) runs once on the concatenated
    attention outputs. The importance output is a *partial sum* over this
    shard's heads — the host reduces partials across shards.

    The attention itself is pure jnp on both kernel impls (the Pallas
    grids assume full-head tensors; numerics agree within the tested
    kernel tolerance, mirroring :func:`decode_layer_batched`).

    ABI:
      inputs:  h ``[n, d]``; mask ``[n]``; positions ``[n]`` int32;
               last_idx ``[]`` int32; ln1 ``[d]``;
               wq_s/wk_s/wv_s ``[d, (H/D)·dh]`` column slices.
      outputs: (attn ``[n, (H/D)·dh]``, k ``[H/D, n, dh]``,
                v ``[H/D, n, dh]``, s_partial ``[n]``).
    """
    del use_pallas  # see docstring: jnp attention on both paths
    heads_s = _shard_heads(wq_s, cfg.d_head)
    x = rms_norm(h, ln1)
    angles = rope_angles(positions, cfg.d_head, cfg.rope_theta)
    q, k, v = qkv_project(x, wq_s, wk_s, wv_s, heads_s, cfg.d_head, angles)
    attn = ref.ref_attention(q, k, v, mask, causal=True)  # [H/D, n, dh]
    attn = jnp.transpose(attn, (1, 0, 2)).reshape(h.shape[0], heads_s * cfg.d_head)
    q_last = jax.lax.dynamic_index_in_dim(q, last_idx, axis=1, keepdims=False)
    s = ref.ref_importance(q_last, k, mask) * _partial_scale(heads_s, cfg.n_heads)
    return attn, k, v, s


def layer_tail(cfg, h, attn, mask, wo, ln2, wg, wu, wd):
    """Combine stage of a sharded prefill-shaped layer.

    ``attn`` is the head-order concatenation of the shards'
    :func:`layer_shard` outputs (``[n, d]``); this reproduces the
    ``wo``-projection + MLP half of :func:`layer_fwd` exactly.

    ABI: inputs h ``[n, d]``; attn ``[n, d]``; mask ``[n]``; wo ``[d, d]``;
         ln2 ``[d]``; wg/wu ``[d, ff]``; wd ``[ff, d]``. Output h' ``[n, d]``.
    """
    h = h + (attn * mask[:, None]) @ wo
    x2 = rms_norm(h, ln2)
    return h + swiglu(x2, wg, wu, wd) * mask[:, None]


def decode_shard(cfg, use_pallas, x, pos, cur_idx, k_cache, v_cache, mask,
                 ln1, wq_s, wk_s, wv_s):
    """Per-head-shard half of a single-token decode step.

    The shard's cache carries only its own heads (``[H/D, n, dh]``) — the
    rust side keeps one paged block list per shard per layer, so nothing
    is re-laid-out when sharding.

    ABI:
      inputs:  x ``[d]``; pos ``[]`` int32; cur_idx ``[]`` int32;
               k_cache/v_cache ``[H/D, n, dh]``; mask ``[n]``; ln1 ``[d]``;
               wq_s/wk_s/wv_s ``[d, (H/D)·dh]``.
      outputs: (attn ``[(H/D)·dh]``, k_new ``[H/D, dh]``,
                v_new ``[H/D, dh]``, s_partial ``[n]``).
    """
    del use_pallas
    heads_s = _shard_heads(wq_s, cfg.d_head)
    xi = rms_norm(x, ln1)[None, :]
    angles = rope_angles(jnp.reshape(pos, (1,)), cfg.d_head, cfg.rope_theta)
    q, k, v = qkv_project(xi, wq_s, wk_s, wv_s, heads_s, cfg.d_head, angles)
    k_new = k[:, 0, :]
    v_new = v[:, 0, :]
    k_full = jax.lax.dynamic_update_index_in_dim(k_cache, k_new, cur_idx, axis=1)
    v_full = jax.lax.dynamic_update_index_in_dim(v_cache, v_new, cur_idx, axis=1)
    q1 = q[:, 0, :]
    out, s = ref.ref_decode_attention(q1, k_full, v_full, mask)
    s = s * _partial_scale(heads_s, cfg.n_heads)
    return out.reshape(heads_s * cfg.d_head), k_new, v_new, s


def decode_tail(cfg, x, attn, wo, ln2, wg, wu, wd):
    """Combine stage of a sharded decode step (wo-projection + MLP).

    ABI: inputs x ``[d]``; attn ``[d]`` (head-order concat of shard
         outputs); 5 tail params. Output x' ``[d]``.
    """
    x = x + attn @ wo
    x2 = rms_norm(x, ln2)
    return x + swiglu(x2, wg, wu, wd)


def decode_shard_batched(cfg, use_pallas, x, pos, cur_idx, k_cache, v_cache,
                         mask, ln1, wq_s, wk_s, wv_s):
    """Per-head-shard half of a fused decode batch.

    Row ``b`` computes exactly what :func:`decode_shard` computes for that
    request; padding rows (zero x, zero mask) stay exactly zero.

    ABI:
      inputs:  x ``[B, d]``; pos/cur_idx ``[B]`` int32;
               k_cache/v_cache ``[B, H/D, n, dh]``; mask ``[B, n]``;
               ln1 ``[d]``; wq_s/wk_s/wv_s ``[d, (H/D)·dh]``.
      outputs: (attn ``[B, (H/D)·dh]``, k_new ``[B, H/D, dh]``,
                v_new ``[B, H/D, dh]``, s_partial ``[B, n]``).
    """
    del use_pallas
    heads_s = _shard_heads(wq_s, cfg.d_head)
    xi = rms_norm(x, ln1)  # [B, d]
    angles = rope_angles(pos, cfg.d_head, cfg.rope_theta)  # [B, dh/2]
    q, k, v = qkv_project(xi, wq_s, wk_s, wv_s, heads_s, cfg.d_head, angles)
    k_new = jnp.transpose(k, (1, 0, 2))  # [B, H/D, dh]
    v_new = jnp.transpose(v, (1, 0, 2))
    q_b = jnp.transpose(q, (1, 0, 2))

    def scatter(cache, row, idx):
        return jax.lax.dynamic_update_index_in_dim(cache, row, idx, axis=1)

    k_full = jax.vmap(scatter)(k_cache, k_new, cur_idx)
    v_full = jax.vmap(scatter)(v_cache, v_new, cur_idx)
    out, s = batched_decode_attention(q_b, k_full, v_full, mask)
    s = s * _partial_scale(heads_s, cfg.n_heads)
    return out.reshape(x.shape[0], heads_s * cfg.d_head), k_new, v_new, s


# Combine stage of a sharded fused decode batch: the single-token tail is
# shape-polymorphic (every op broadcasts over a leading batch axis), so
# the batched entry *is* :func:`decode_tail` lowered at ``[B, d]``.
# ABI: x ``[B, d]``; attn ``[B, d]``; 5 tail params → x' ``[B, d]``.
decode_tail_batched = decode_tail


def logits_shard(cfg, tp, shard, x, ln_f, emb_s):
    """Per-device partial of the logits head.

    The tied unembedding contracts over ``d_model``; shard ``s`` owns
    columns ``[s·d/D, (s+1)·d/D)`` of ``emb`` and the matching slice of
    the normalized hidden vector, so summing the D partials reproduces
    :func:`logits_head` (all-reduce on the host). ``rms_norm`` needs the
    *full* ``x`` and is recomputed per shard (it is O(d)).

    ABI: inputs x ``[d]``, ln_f ``[d]``, emb_s ``[vocab, d/D]``;
         output partial logits ``[vocab]``.
    """
    dc = cfg.d_model // tp
    xn = rms_norm(x, ln_f)
    return xn[shard * dc:(shard + 1) * dc] @ emb_s.T


def logits_shard_batched(cfg, tp, shard, x, ln_f, emb_s):
    """Batched :func:`logits_shard`: x ``[B, d]`` → partial ``[B, vocab]``."""
    dc = cfg.d_model // tp
    xn = rms_norm(x, ln_f)
    return xn[:, shard * dc:(shard + 1) * dc] @ emb_s.T


def calib_probe(cfg, x_emb, mask, positions, *stacked):
    """Offline rollout/attention probe over all L layers (calibration path).

    Runs the vanilla forward and records, per layer: the head-averaged raw
    attention map and the accumulated rollout
    ``R^l = (a A^l + (1-a) I) R^{l-1}`` (paper Eqs. 2–3; the accumulation
    itself is the Pallas :func:`rollout_step` kernel).

    ABI:
      inputs:  x_emb ``[n, d]``; mask ``[n]``; positions ``[n]`` int32;
               9 params stacked ``[L, ...]``.
      outputs: (rollout_stack ``[L, n, n]``, attn_stack ``[L, n, n]``).
    """
    n = x_emb.shape[0]
    angles = rope_angles(positions, cfg.d_head, cfg.rope_theta)
    params = _layer_dict(stacked)
    alpha = cfg.rollout_alpha

    def step(carry, layer_params):
        h, r = carry
        x = rms_norm(h, layer_params["ln1"])
        q, k, v = qkv_project(
            x, layer_params["wq"], layer_params["wk"], layer_params["wv"],
            cfg.n_heads, cfg.d_head, angles,
        )
        a_bar = ref.ref_attention_probs(q, k, mask, causal=True)  # [n, n]
        r = rollout_step(a_bar, r, alpha)
        attn = ref.ref_attention(q, k, v, mask, causal=True)
        attn = jnp.transpose(attn, (1, 0, 2)).reshape(n, cfg.d_model)
        h = h + (attn * mask[:, None]) @ layer_params["wo"]
        x2 = rms_norm(h, layer_params["ln2"])
        h = h + swiglu(x2, layer_params["wg"], layer_params["wu"], layer_params["wd"]) * mask[:, None]
        return (h, r), (r, a_bar)

    init = (x_emb, jnp.eye(n, dtype=jnp.float32))
    (_, _), (rollout_stack, attn_stack) = jax.lax.scan(step, init, params)
    return rollout_stack, attn_stack


# ---------------------------------------------------------------- training path


def batched_attention(q, k, v, mask):
    """Causal MHA over a batch: q/k/v ``[B, H, n, dh]``, mask ``[B, n]``."""
    b, h, n, dh = q.shape
    scale = 1.0 / jnp.sqrt(jnp.float32(dh))
    logits = jnp.einsum("bhqd,bhkd->bhqk", q, k) * scale
    bias = jnp.where(mask[:, None, None, :] > 0.5, 0.0, ref.NEG_INF)
    tri = jnp.where(
        jnp.arange(n)[None, :] <= jnp.arange(n)[:, None], 0.0, ref.NEG_INF
    )
    logits = logits + bias + tri[None, None, :, :]
    m = jnp.maximum(jnp.max(logits, axis=-1, keepdims=True), ref.NEG_INF / 2)
    p = jnp.exp(logits - m)
    p = p / jnp.maximum(jnp.sum(p, axis=-1, keepdims=True), 1e-30)
    return jnp.einsum("bhqk,bhkd->bhqd", p, v)


def train_forward(cfg, params, tokens, mask):
    """Teacher-forced logits ``[B, n, vocab]`` for training.

    ``params`` is the full pytree: ``{"emb", "ln_f", "layers": {name: [L, ...]}}``.
    """
    b, n = tokens.shape
    h = params["emb"][tokens]  # [B, n, d]
    positions = jnp.broadcast_to(jnp.arange(n, dtype=jnp.int32), (b, n))
    angles = rope_angles(positions, cfg.d_head, cfg.rope_theta)  # [B, n, half]

    def step(h, layer_params):
        x = rms_norm(h, layer_params["ln1"])

        def heads(w):
            return (x @ w).reshape(b, n, cfg.n_heads, cfg.d_head)

        q = apply_rope(heads(layer_params["wq"]), angles)
        k = apply_rope(heads(layer_params["wk"]), angles)
        v = heads(layer_params["wv"])
        q, k, v = (jnp.transpose(t, (0, 2, 1, 3)) for t in (q, k, v))
        attn = batched_attention(q, k, v, mask)
        attn = jnp.transpose(attn, (0, 2, 1, 3)).reshape(b, n, cfg.d_model)
        h = h + (attn * mask[:, :, None]) @ layer_params["wo"]
        x2 = rms_norm(h, layer_params["ln2"])
        h = h + swiglu(x2, layer_params["wg"], layer_params["wu"], layer_params["wd"]) * mask[:, :, None]
        return h, None

    h, _ = jax.lax.scan(step, h, params["layers"])
    h = rms_norm(h, params["ln_f"])
    return h @ params["emb"].T


def init_params(cfg, key):
    """Initialize the parameter pytree (scaled-normal, zero-mean)."""
    keys = jax.random.split(key, 8)
    d, ff, l = cfg.d_model, cfg.d_ff, cfg.n_layers

    def normal(k, shape, scale):
        return jax.random.normal(k, shape, jnp.float32) * scale

    return {
        "emb": normal(keys[0], (cfg.vocab, d), 0.02),
        "ln_f": jnp.ones((d,), jnp.float32),
        "layers": {
            "ln1": jnp.ones((l, d), jnp.float32),
            "wq": normal(keys[1], (l, d, d), d ** -0.5),
            "wk": normal(keys[2], (l, d, d), d ** -0.5),
            "wv": normal(keys[3], (l, d, d), d ** -0.5),
            "wo": normal(keys[4], (l, d, d), (2 * l * d) ** -0.5),
            "ln2": jnp.ones((l, d), jnp.float32),
            "wg": normal(keys[5], (l, d, ff), d ** -0.5),
            "wu": normal(keys[6], (l, d, ff), d ** -0.5),
            "wd": normal(keys[7], (l, ff, d), (2 * l * ff) ** -0.5),
        },
    }
