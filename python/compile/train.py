"""Build-time training of the simulated AV-LLMs (L2, python-only).

Trains each model config on the avsynth task mixture with a hand-written
Adam (no optax on this image) and exports ``weights.bin`` + loss curve.
Runs once from ``make artifacts``; never on the serving path.

Usage: python -m compile.train [--model vl2sim] [--steps N] [--out DIR]
"""

import argparse
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from . import avsynth
from .config import CONFIGS, WEIGHT_ALIASES
from .export import save_weights
from .model import init_params, train_forward
from . import vocab as V


def make_batch(cfg, rng_indices, base_seed, bucket, dataset="train"):
    """Assemble a teacher-forced batch from avsynth samples.

    Returns (tokens [B, n], attn_mask [B, n], loss_mask [B, n]) where
    ``loss_mask[i] == 1`` at positions whose *next*-token target is an
    answer token.
    """
    b = len(rng_indices)
    tokens = np.zeros((b, bucket), dtype=np.int32)
    attn_mask = np.zeros((b, bucket), dtype=np.float32)
    loss_mask = np.zeros((b, bucket), dtype=np.float32)
    for i, idx in enumerate(rng_indices):
        s = avsynth.gen_sample(cfg.layout, dataset, int(idx), base_seed)
        seq = s.prompt + s.answer
        assert len(seq) <= bucket, (len(seq), bucket)
        tokens[i, :len(seq)] = seq
        attn_mask[i, :len(seq)] = 1.0
        # Positions len(prompt)-1 .. len(seq)-2 predict the answer tokens.
        loss_mask[i, len(s.prompt) - 1:len(seq) - 1] = 1.0
    return jnp.asarray(tokens), jnp.asarray(attn_mask), jnp.asarray(loss_mask)


def loss_fn(cfg, params, tokens, attn_mask, loss_mask):
    logits = train_forward(cfg, params, tokens, attn_mask)  # [B, n, vocab]
    targets = jnp.roll(tokens, -1, axis=1)
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, targets[..., None], axis=-1)[..., 0]
    return jnp.sum(nll * loss_mask) / jnp.maximum(jnp.sum(loss_mask), 1.0)


def adam_init(params):
    zeros = jax.tree_util.tree_map(jnp.zeros_like, params)
    return {"m": zeros, "v": jax.tree_util.tree_map(jnp.zeros_like, params), "t": jnp.zeros((), jnp.int32)}


def adam_update(params, grads, state, lr, b1=0.9, b2=0.999, eps=1e-8):
    t = state["t"] + 1
    m = jax.tree_util.tree_map(lambda m, g: b1 * m + (1 - b1) * g, state["m"], grads)
    v = jax.tree_util.tree_map(lambda v, g: b2 * v + (1 - b2) * g * g, state["v"], grads)
    mhat_scale = 1.0 / (1 - b1 ** t.astype(jnp.float32))
    vhat_scale = 1.0 / (1 - b2 ** t.astype(jnp.float32))
    new_params = jax.tree_util.tree_map(
        lambda p, m_, v_: p - lr * (m_ * mhat_scale) / (jnp.sqrt(v_ * vhat_scale) + eps),
        params, m, v,
    )
    return new_params, {"m": m, "v": v, "t": t}


def lr_schedule(step, total, base_lr, warmup=50):
    warm = jnp.minimum(step / warmup, 1.0)
    progress = jnp.clip((step - warmup) / jnp.maximum(total - warmup, 1), 0.0, 1.0)
    cosine = 0.5 * (1.0 + jnp.cos(jnp.pi * progress))
    return base_lr * warm * (0.03 + 0.97 * cosine)


def clip_grads(grads, max_norm=1.0):
    """Global-norm gradient clipping (stabilizes the retrieval heads)."""
    leaves = jax.tree_util.tree_leaves(grads)
    norm = jnp.sqrt(sum(jnp.sum(g * g) for g in leaves))
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree_util.tree_map(lambda g: g * scale, grads)


def answer_accuracy(cfg, params, tokens, attn_mask, loss_mask):
    """Teacher-forced exact-answer accuracy: every answer token argmax-correct."""
    logits = train_forward(cfg, params, tokens, attn_mask)
    targets = jnp.roll(tokens, -1, axis=1)
    pred = jnp.argmax(logits, axis=-1)
    tok_ok = jnp.where(loss_mask > 0, (pred == targets).astype(jnp.float32), 1.0)
    sample_ok = jnp.min(tok_ok, axis=1)
    return float(jnp.mean(sample_ok))


def train_model(cfg, out_dir, steps=None, log_every=25, extend=False):
    """Train from scratch, or — with ``extend=True`` and an existing
    checkpoint — continue training (used to add task emphasis without a
    full retrain; the avsynth train stream controls the mixture)."""
    steps = steps or cfg.train_steps
    bucket = cfg.prefill_buckets[0]
    key = jax.random.PRNGKey(cfg.train_seed)
    if extend and os.path.exists(os.path.join(out_dir, "weights.bin")):
        from .export import load_weights
        loaded = load_weights(out_dir, cfg)
        params = {
            "emb": jnp.asarray(loaded["emb"]),
            "ln_f": jnp.asarray(loaded["ln_f"]),
            "layers": {k: jnp.asarray(v) for k, v in loaded["layers"].items()},
        }
        print(f"[{cfg.name}] extending from existing checkpoint")
    else:
        params = init_params(cfg, key)
    opt = adam_init(params)

    @jax.jit
    def step_fn(params, opt, tokens, attn_mask, loss_mask, lr):
        loss, grads = jax.value_and_grad(
            lambda p: loss_fn(cfg, p, tokens, attn_mask, loss_mask)
        )(params)
        grads = clip_grads(grads)
        params, opt = adam_update(params, grads, opt, lr)
        return params, opt, loss

    curve = []
    t0 = time.time()
    # Extension runs draw from a disjoint index range so they see fresh
    # samples under the (possibly re-weighted) train mixture.
    base_idx = 5_000_000 if extend else 0
    for step in range(steps):
        idx = base_idx + np.arange(step * cfg.train_batch, (step + 1) * cfg.train_batch)
        tokens, attn_mask, loss_mask = make_batch(cfg, idx, cfg.train_seed, bucket)
        base_lr = cfg.train_lr * (0.5 if extend else 1.0)  # gentler fine-tune
        lr = lr_schedule(jnp.float32(step), steps, base_lr)
        params, opt, loss = step_fn(params, opt, tokens, attn_mask, loss_mask, lr)
        if step % log_every == 0 or step == steps - 1:
            loss_v = float(loss)
            curve.append((step, loss_v))
            print(f"[{cfg.name}] step {step:4d}  loss {loss_v:.4f}  "
                  f"({time.time() - t0:.0f}s)", flush=True)

    # Held-out evaluation (indices far beyond the training range).
    accs = []
    for ev in range(4):
        idx = np.arange(10_000_000 + ev * cfg.train_batch, 10_000_000 + (ev + 1) * cfg.train_batch)
        tokens, attn_mask, loss_mask = make_batch(cfg, idx, cfg.train_seed, bucket)
        accs.append(answer_accuracy(cfg, params, tokens, attn_mask, loss_mask))
    acc = float(np.mean(accs))
    print(f"[{cfg.name}] held-out teacher-forced answer accuracy: {acc:.3f}")

    os.makedirs(out_dir, exist_ok=True)
    save_weights(params, out_dir)
    with open(os.path.join(out_dir, "train_loss.csv"), "w") as f:
        f.write("step,loss\n")
        for s, l in curve:
            f.write(f"{s},{l:.6f}\n")
    with open(os.path.join(out_dir, "train_summary.txt"), "w") as f:
        f.write(f"model={cfg.name} steps={steps} final_loss={curve[-1][1]:.4f} "
                f"heldout_acc={acc:.4f}\n")
    return acc


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--model", default="all", help="config name or 'all'")
    ap.add_argument("--steps", type=int, default=None)
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--extend", action="store_true",
                    help="continue training an existing checkpoint")
    args = ap.parse_args()

    names = [n for n in CONFIGS if n not in WEIGHT_ALIASES] if args.model == "all" else [args.model]
    for name in names:
        cfg = CONFIGS[name]
        out_dir = os.path.join(args.out, name)
        if not args.extend and os.path.exists(os.path.join(out_dir, "weights.bin")):
            print(f"[{name}] weights exist, skipping (delete to retrain)")
            continue
        train_model(cfg, out_dir, steps=args.steps, extend=args.extend)


if __name__ == "__main__":
    main()
