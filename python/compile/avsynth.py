"""Synthetic audio-visual task suite ("avsynth").

Substitute for AVQA / MUSIC-AVQA / AVHBench (DESIGN.md §2): each sample
plants class-bearing *evidence tokens* inside streams of modality noise,
and the answer is a deterministic function of the multimodal evidence.
Evidence is concentrated early in each modality (first frames / first
audio slots) — the property FastAV's rollout analysis detects in real
AV-LLMs — so pruning strategies separate exactly as in the paper: keeping
early tokens is safe, dropping informative tokens is catastrophic.

This module is mirrored in ``rust/src/avsynth/``; both sides generate
bit-identical samples from the same (base_seed, dataset, index) triple via
the shared SplitMix64. Cross-language reference vectors are pinned in
``python/tests/test_avsynth.py`` and the rust test suite.
"""

from dataclasses import dataclass, field

from . import vocab as V
from .rng import SplitMix64, derive_seed

# Modality codes for the per-token segment map (shared with rust).
SEG_CTRL = 0
SEG_VIS = 1
SEG_AUD = 2
SEG_TEXT = 3

# Dataset stream ids for seed derivation (shared with rust).
STREAM_TRAIN = 0
STREAM_AVQA = 1
STREAM_MUSIC = 2
STREAM_AVHBENCH = 3
STREAM_CALIB = 4

DATASET_STREAMS = {
    "train": STREAM_TRAIN,
    "avqa": STREAM_AVQA,
    "musicavqa": STREAM_MUSIC,
    "avhbench": STREAM_AVHBENCH,
    "calib": STREAM_CALIB,
}

EVIDENCE_FRAMES = 2    # scene evidence lives in the first 2 frames
EVIDENCE_AUD_SLOTS = 4  # sound evidence lives in the first 4 audio slots
BEAT_REGION = 12       # beat markers land in the first 12 audio slots
MAX_BEATS = 5


@dataclass
class LayoutCfg:
    """Modality layout of the prompt (mirrors rust ``tokens::Layout``).

    ``interleaved=False`` — VideoLLaMA2-style: ``BOS | all vis | all aud |
    text``. ``interleaved=True`` — video-SALMONN2-style: ``BOS | per-frame
    (vis then aud) | text``.
    """

    frames: int = 8
    vis_per_frame: int = 8
    aud_len: int = 24          # sequential layout: total audio tokens
    aud_per_frame: int = 3     # interleaved layout: audio tokens per frame
    interleaved: bool = False

    def audio_tokens(self) -> int:
        return self.frames * self.aud_per_frame if self.interleaved else self.aud_len

    def vis_tokens(self) -> int:
        return self.frames * self.vis_per_frame

    def prompt_len_max(self) -> int:
        # BOS + modality tokens + [SEP, qword, arg, SEP]
        return 1 + self.vis_tokens() + self.audio_tokens() + 4


@dataclass
class Sample:
    """One synthetic AV sample: prompt token ids + expected answer.

    ``segments[i]``/``frame_of[i]`` describe token *i* of the prompt:
    modality code and owning frame (-1 when not frame-scoped). The rust
    pruning policies consume this map.
    """

    dataset: str
    subtask: str
    index: int
    prompt: list = field(default_factory=list)
    answer: list = field(default_factory=list)   # includes trailing EOS
    segments: list = field(default_factory=list)
    frame_of: list = field(default_factory=list)
    scene: int = -1
    sound: int = -1
    beats: int = -1


def _fill_streams(rng, cfg, scene, sound, beats):
    """Generate the visual and audio token streams with planted evidence."""
    vis = []
    for f in range(cfg.frames):
        frame = [V.VIS_NOISE_BASE + rng.next_below(V.VIS_NOISE_COUNT)
                 for _ in range(cfg.vis_per_frame)]
        if f < EVIDENCE_FRAMES:
            slot = rng.next_below(cfg.vis_per_frame)
            frame[slot] = V.scene_token(scene)
        vis.append(frame)

    n_aud = cfg.audio_tokens()
    aud = [V.AUD_NOISE_BASE + rng.next_below(V.AUD_NOISE_COUNT)
           for _ in range(n_aud)]
    slot = rng.next_below(min(EVIDENCE_AUD_SLOTS, n_aud))
    aud[slot] = V.sound_token(sound)
    if beats > 0:
        # Distinct beat slots inside the (early) beat region, skipping the
        # sound-evidence slot.
        region = min(BEAT_REGION, n_aud)
        placed = 0
        while placed < beats:
            b = rng.next_below(region)
            if aud[b] == V.BEAT or b == slot:
                continue
            aud[b] = V.BEAT
            placed += 1
    return vis, aud


def _assemble(cfg, vis, aud, question):
    """Concatenate modality streams per layout; build the segment map."""
    prompt, segs, frames = [V.BOS], [SEG_CTRL], [-1]
    if cfg.interleaved:
        ap = cfg.aud_per_frame
        for f in range(cfg.frames):
            for t in vis[f]:
                prompt.append(t); segs.append(SEG_VIS); frames.append(f)
            for a in aud[f * ap:(f + 1) * ap]:
                prompt.append(a); segs.append(SEG_AUD); frames.append(f)
    else:
        for f in range(cfg.frames):
            for t in vis[f]:
                prompt.append(t); segs.append(SEG_VIS); frames.append(f)
        for a in aud:
            prompt.append(a); segs.append(SEG_AUD); frames.append(-1)
    for t in question:
        prompt.append(t); segs.append(SEG_TEXT); frames.append(-1)
    return prompt, segs, frames


def _question(qword, arg=None):
    q = [V.SEP, qword]
    if arg is not None:
        q.append(arg)
    q.append(V.SEP)
    return q


def gen_sample(cfg: LayoutCfg, dataset: str, index: int, base_seed: int) -> Sample:
    """Generate sample ``index`` of ``dataset`` deterministically.

    The (dataset, index, base_seed) triple fully determines the sample on
    both the python and rust implementations.
    """
    stream = DATASET_STREAMS[dataset]
    rng = SplitMix64(derive_seed(base_seed, stream, index))

    scene = rng.next_below(V.NUM_CLASSES)
    # Default: sound drawn independently (may or may not match the scene).
    sound = rng.next_below(V.NUM_CLASSES)
    beats = -1
    subtask = ""
    question, answer = [], []

    if dataset in ("train", "calib"):
        # Training/calibration mixture, weighted toward the relational
        # tasks (hallucination, matching) which need far more examples to
        # learn than the retrieval tasks. Weights (mirrored in rust):
        #   what_scene 1, what_sound 1, scene_sound 1, beats 1,
        #   instrument 1, hallucination 4, matching 4, captioning 1.
        r = rng.next_below(14)
        bounds = [1, 2, 3, 4, 5, 9, 13, 14]       # cumulative
        picks_ = [0, 1, 2, 3, 4, 5, 6, 8]
        pick = next(p for b, p in zip(bounds, picks_) if r < b)
    elif dataset == "avqa":
        pick = rng.next_below(3)            # 0..2
    elif dataset == "musicavqa":
        pick = 3 + rng.next_below(2)        # 3..4
    elif dataset == "avhbench":
        pick = 5 + rng.next_below(3)        # 5..7 (3 subtasks)
        if pick == 7:
            pick = 8                        # captioning
    else:
        raise ValueError(dataset)

    if pick == 0:
        subtask = "what_scene"
        question = _question(V.Q_WHAT_SCENE)
        answer = [V.scene_token(scene), V.EOS]
    elif pick == 1:
        subtask = "what_sound"
        question = _question(V.Q_WHAT_SOUND)
        answer = [V.sound_token(sound), V.EOS]
    elif pick == 2:
        subtask = "scene_sound"
        question = _question(V.Q_SCENE_SOUND)
        answer = [V.scene_token(scene), V.sound_token(sound), V.EOS]
    elif pick == 3:
        subtask = "how_many_beats"
        beats = rng.next_below(MAX_BEATS + 1)
        question = _question(V.Q_HOW_MANY_BEATS)
        answer = [V.digit_token(beats), V.EOS]
    elif pick == 4:
        subtask = "which_instrument"
        question = _question(V.Q_WHICH_INSTRUMENT)
        answer = [V.sound_token(sound), V.EOS]
    elif pick == 5:
        subtask = "hallucination"
        # 50%: ask about the present class; 50%: an absent one.
        ask_sound = rng.chance(0.5)
        present = rng.chance(0.5)
        actual = sound if ask_sound else scene
        if present:
            probe = actual
        else:
            probe = (actual + 1 + rng.next_below(V.NUM_CLASSES - 1)) % V.NUM_CLASSES
        tok = V.sound_token(probe) if ask_sound else V.scene_token(probe)
        qw = V.Q_IS_THERE_SOUND if ask_sound else V.Q_IS_THERE_SCENE
        question = _question(qw, tok)
        answer = [V.YES if present else V.NO, V.EOS]
    elif pick == 6:
        subtask = "matching"
        matched = rng.chance(0.5)
        if matched:
            sound = scene
        else:
            sound = (scene + 1 + rng.next_below(V.NUM_CLASSES - 1)) % V.NUM_CLASSES
        question = _question(V.Q_AV_MATCH)
        answer = [V.YES if matched else V.NO, V.EOS]
    elif pick == 8:
        subtask = "captioning"
        question = _question(V.Q_DESCRIBE)
        answer = [V.scene_token(scene), V.sound_token(sound), V.EOS]
    else:
        raise AssertionError(pick)

    if beats < 0:
        beats = 0
    vis, aud = _fill_streams(rng, cfg, scene, sound, beats)
    prompt, segs, frames = _assemble(cfg, vis, aud, question)
    return Sample(
        dataset=dataset, subtask=subtask, index=index,
        prompt=prompt, answer=answer, segments=segs, frame_of=frames,
        scene=scene, sound=sound, beats=beats,
    )


# Canonical layouts for the two simulated AV-LLMs (mirrors rust).
VL2SIM_LAYOUT = LayoutCfg(frames=8, vis_per_frame=8, aud_len=24, interleaved=False)
SALMSIM_LAYOUT = LayoutCfg(frames=8, vis_per_frame=8, aud_per_frame=3, interleaved=True)
# Long-context layout for latency-scaling benches (prefill bucket 512).
VL2SIM_LONG_LAYOUT = LayoutCfg(frames=24, vis_per_frame=16, aud_len=96, interleaved=False)
