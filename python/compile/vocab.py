"""Structured vocabulary for the synthetic AV task suite.

Shared layout with ``rust/src/tokens/vocab.rs`` — keep the two in sync
(pinned by cross-implementation tests). Vocabulary size is 256.

Layout:
  0..15    control + answer words (PAD, BOS, EOS, SEP, YES, NO, ...)
  16..31   scene classes   (visual evidence + answer words)
  32..47   sound classes   (audio evidence + answer words)
  48..57   digits 0-9      (counting answers)
  58..63   reserved
  64..127  visual noise tokens
  128..191 audio noise tokens
  192..207 question words (one per question type)
  208..223 beat marker + misc audio events
  224..255 reserved
"""

VOCAB_SIZE = 256

PAD = 0
BOS = 1
EOS = 2
SEP = 3
YES = 4
NO = 5

NUM_CLASSES = 16

SCENE_BASE = 16   # scene class c -> token SCENE_BASE + c
SOUND_BASE = 32   # sound class c -> token SOUND_BASE + c
DIGIT_BASE = 48   # digit k (0..9) -> token DIGIT_BASE + k

VIS_NOISE_BASE = 64
VIS_NOISE_COUNT = 64
AUD_NOISE_BASE = 128
AUD_NOISE_COUNT = 64

# Question-word tokens (one per question type).
Q_WHAT_SCENE = 192
Q_WHAT_SOUND = 193
Q_SCENE_SOUND = 194
Q_HOW_MANY_BEATS = 195
Q_WHICH_INSTRUMENT = 196
Q_IS_THERE_SCENE = 197
Q_IS_THERE_SOUND = 198
Q_AV_MATCH = 199
Q_DESCRIBE = 200

BEAT = 208  # audio beat marker for the counting task


def scene_token(c: int) -> int:
    assert 0 <= c < NUM_CLASSES
    return SCENE_BASE + c


def sound_token(c: int) -> int:
    assert 0 <= c < NUM_CLASSES
    return SOUND_BASE + c


def digit_token(k: int) -> int:
    assert 0 <= k <= 9
    return DIGIT_BASE + k


def is_vis_noise(t: int) -> bool:
    return VIS_NOISE_BASE <= t < VIS_NOISE_BASE + VIS_NOISE_COUNT


def is_aud_noise(t: int) -> bool:
    return AUD_NOISE_BASE <= t < AUD_NOISE_BASE + AUD_NOISE_COUNT
