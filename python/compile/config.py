"""Model configurations and the AOT bucket grid.

Mirrored by ``rust/src/model/config.rs`` — the rust side reads the same
values from ``artifacts/<model>/model.json`` written by ``aot.py``, so this
file is the single authoritative definition.
"""

from dataclasses import dataclass, asdict, field

from .avsynth import (
    LayoutCfg,
    SALMSIM_LAYOUT,
    VL2SIM_LAYOUT,
    VL2SIM_LONG_LAYOUT,
)


@dataclass
class ModelCfg:
    """AV-LLM decoder hyperparameters + AOT bucket grid.

    ``mid_layer`` is the FastAV global-pruning layer (L/2 in the paper —
    layer 14 of VideoLLaMA2's 28). Buckets are the static sequence lengths
    artifacts are compiled at; the rust runtime picks the smallest bucket
    that fits (DESIGN.md §3). All buckets are multiples of 16 so Pallas
    tile sizes divide evenly.
    """

    name: str = "vl2sim"
    vocab: int = 256
    d_model: int = 128
    n_heads: int = 4
    d_head: int = 32
    n_layers: int = 8
    mid_layer: int = 4
    d_ff: int = 256
    rope_theta: float = 10000.0
    rollout_alpha: float = 0.6
    layout: LayoutCfg = field(default_factory=lambda: VL2SIM_LAYOUT)
    prefill_buckets: tuple = (128,)
    seq_buckets: tuple = (32, 48, 64, 96, 128)   # back layers + decode
    calib_buckets: tuple = (128,)
    # Decode batch sizes: `decode_batch<b>_<n>.hlo.txt` artifacts are
    # emitted per (batch bucket × seq bucket) so a replica can fuse up to
    # max(batch_buckets) in-flight single-token decode steps into one
    # dispatch (continuous batched decode). Empty = no batched artifacts.
    batch_buckets: tuple = (2, 4, 8)
    # Tensor-parallel degree: when > 1, additionally emit head-sharded
    # artifacts (`layer_shard<s>of<D>_<n>`, `decode_shard<s>of<D>_<n>`,
    # `logits_shard<s>of<D>`, batched variants, and the `*_tail` combine
    # stages) so the rust device-mesh backend can split one replica's
    # model across D devices, each owning n_heads/D attention heads. The
    # fused single-device artifacts are always emitted too — tp_degree=1
    # execution never touches the sharded set.
    tp_degree: int = 1
    # Emit per-split front artifacts (frontsplit<m>_<n>.hlo.txt) for the
    # pruning-start-layer sweep (paper Fig. 4).
    emit_splits: bool = False
    # Training hyperparameters (build-time only).
    train_steps: int = 1500
    train_batch: int = 16
    train_lr: float = 2e-3
    train_seed: int = 1234

    def __post_init__(self):
        assert self.d_model == self.n_heads * self.d_head
        assert 0 < self.mid_layer < self.n_layers
        assert self.tp_degree >= 1
        if self.tp_degree > 1:
            # Heads are the shard axis; the logits head shards d_model.
            assert self.n_heads % self.tp_degree == 0
            assert self.d_model % self.tp_degree == 0

    def to_json_dict(self):
        d = asdict(self)
        d["layout"] = asdict(self.layout)
        d["prefill_buckets"] = list(self.prefill_buckets)
        d["seq_buckets"] = list(self.seq_buckets)
        d["calib_buckets"] = list(self.calib_buckets)
        d["batch_buckets"] = list(self.batch_buckets)
        return d


VL2SIM = ModelCfg(name="vl2sim", layout=VL2SIM_LAYOUT, emit_splits=True, tp_degree=2)

SALMSIM = ModelCfg(name="salmsim", layout=SALMSIM_LAYOUT)

# Long-context vl2sim variant for latency-scaling benches: same weights as
# vl2sim (identical architecture), larger buckets. No separate training.
VL2SIM_LONG = ModelCfg(
    name="vl2sim_long",
    layout=VL2SIM_LONG_LAYOUT,
    prefill_buckets=(512,),
    seq_buckets=(64, 128, 192, 256, 384, 512),
    calib_buckets=(512,),
)

# Miniature config for fast rust integration tests.
TINY = ModelCfg(
    name="tiny",
    d_model=32,
    n_heads=2,
    d_head=16,
    n_layers=4,
    mid_layer=2,
    d_ff=64,
    layout=LayoutCfg(frames=2, vis_per_frame=4, aud_len=6, interleaved=False),
    prefill_buckets=(32,),
    seq_buckets=(16, 32),
    calib_buckets=(32,),
    batch_buckets=(2, 4),
    tp_degree=2,
    emit_splits=True,
    train_steps=150,
    train_batch=8,
)

CONFIGS = {c.name: c for c in (VL2SIM, SALMSIM, VL2SIM_LONG, TINY)}

# vl2sim_long shares vl2sim's trained weights.
WEIGHT_ALIASES = {"vl2sim_long": "vl2sim"}
