"""AOT lowering: every L2 entry point → HLO *text* artifacts for rust/PJRT.

HLO text (not ``.serialize()``) is the interchange format: jax ≥ 0.5 emits
HloModuleProtos with 64-bit instruction ids that the xla crate's
xla_extension 0.5.1 rejects; the text parser reassigns ids and round-trips
cleanly (see /opt/xla-example/README.md).

Per model config this emits into ``artifacts/<model>/``:
  * ``prefill_front_<n>.hlo.txt``  (one per prefill bucket)
  * ``back_layer_<n>.hlo.txt``     (one per seq bucket)
  * ``decode_layer_<n>.hlo.txt``   (one per seq bucket)
  * ``decode_batch<b>_<n>.hlo.txt`` (one per batch bucket × seq bucket —
    the fused continuous-batching decode step)
  * ``logits.hlo.txt``
  * ``calib_probe_<n>.hlo.txt``    (one per calib bucket)
  * ``model.json``                 (config + bucket grid + per-entry ABI)

Usage: python -m compile.aot [--out ../artifacts] [--model all]
       [--impl pallas|jnp] [--force]
"""

import argparse
import functools
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from .config import CONFIGS, WEIGHT_ALIASES
from . import model as M


def to_hlo_text(lowered) -> str:
    """stablehlo → XlaComputation → HLO text (the 0.5.1-compatible path)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def spec(shape, dtype=jnp.float32):
    return jax.ShapeDtypeStruct(shape, dtype)


def layer_param_specs(cfg, stack=None):
    """ShapeDtypeStructs for the 9 per-layer params (optionally stacked)."""
    d, ff = cfg.d_model, cfg.d_ff
    shapes = {
        "ln1": (d,), "wq": (d, d), "wk": (d, d), "wv": (d, d), "wo": (d, d),
        "ln2": (d,), "wg": (d, ff), "wu": (d, ff), "wd": (ff, d),
    }
    out = []
    for name in M.LAYER_PARAM_NAMES:
        s = shapes[name]
        if stack is not None:
            s = (stack,) + s
        out.append(spec(s))
    return out


def entry_specs(cfg, entry, n, split=None, batch=None):
    """Input ShapeDtypeStructs for an entry point at bucket n (the rust ABI).

    ``split`` overrides the front-half depth for ``frontsplit`` artifacts
    (the Fig. 4 pruning-start-layer sweep); ``batch`` is the batch bucket
    for ``decode_layer_batched`` artifacts.
    """
    d, h, dh = cfg.d_model, cfg.n_heads, cfg.d_head
    if entry in ("prefill_front", "frontsplit"):
        stack = cfg.mid_layer if split is None else split
        return [spec((n, d)), spec((n,)), spec((n,), jnp.int32)] + \
            layer_param_specs(cfg, stack=stack)
    if entry == "back_layer":
        return [spec((n, d)), spec((n,)), spec((n,), jnp.int32),
                spec((), jnp.int32)] + layer_param_specs(cfg)
    if entry == "decode_layer":
        return [spec((d,)), spec((), jnp.int32), spec((), jnp.int32),
                spec((h, n, dh)), spec((h, n, dh)), spec((n,))] + \
            layer_param_specs(cfg)
    if entry == "decode_layer_batched":
        b = cfg.batch_buckets[0] if batch is None else batch
        return [spec((b, d)), spec((b,), jnp.int32), spec((b,), jnp.int32),
                spec((b, h, n, dh)), spec((b, h, n, dh)), spec((b, n))] + \
            layer_param_specs(cfg)
    if entry == "logits":
        return [spec((d,)), spec((d,)), spec((cfg.vocab, d))]
    if entry == "calib_probe":
        return [spec((n, d)), spec((n,)), spec((n,), jnp.int32)] + \
            layer_param_specs(cfg, stack=cfg.n_layers)
    raise ValueError(entry)


def entry_fn(cfg, entry, use_pallas):
    if entry in ("prefill_front", "frontsplit"):
        return functools.partial(M.prefill_front, cfg, use_pallas)
    if entry == "back_layer":
        return functools.partial(M.back_layer, cfg, use_pallas)
    if entry == "decode_layer":
        return functools.partial(M.decode_layer, cfg, use_pallas)
    if entry == "decode_layer_batched":
        return functools.partial(M.decode_layer_batched, cfg, use_pallas)
    if entry == "logits":
        return functools.partial(M.logits_head, cfg)
    if entry == "calib_probe":
        return functools.partial(M.calib_probe, cfg)
    raise ValueError(entry)


def lower_entry(cfg, entry, n, use_pallas, out_path, force, split=None, batch=None):
    if os.path.exists(out_path) and not force:
        return False
    specs = entry_specs(cfg, entry, n, split=split, batch=batch)
    lowered = jax.jit(entry_fn(cfg, entry, use_pallas)).lower(*specs)
    text = to_hlo_text(lowered)
    with open(out_path, "w") as f:
        f.write(text)
    return True


def abi_of(cfg, entry, n, batch=None):
    return [
        {"shape": list(s.shape), "dtype": str(s.dtype)}
        for s in entry_specs(cfg, entry, n, batch=batch)
    ]


def build_model(cfg, out_root, use_pallas, force):
    out_dir = os.path.join(out_root, cfg.name)
    os.makedirs(out_dir, exist_ok=True)
    built = 0

    # (entry, bucket, split, batch, filename-stem)
    plan = [("prefill_front", n, None, None, f"prefill_front_{n}") for n in cfg.prefill_buckets]
    plan += [("back_layer", n, None, None, f"back_layer_{n}") for n in cfg.seq_buckets]
    plan += [("decode_layer", n, None, None, f"decode_layer_{n}") for n in cfg.seq_buckets]
    # Batched decode: one artifact per (batch bucket × seq bucket); the
    # rust engine picks the smallest (B, cap) pair covering a quantum's
    # decode-ready set and falls back to decode_layer when none fits.
    plan += [("decode_layer_batched", n, None, b, f"decode_batch{b}_{n}")
             for b in cfg.batch_buckets for n in cfg.seq_buckets]
    plan += [("logits", 0, None, None, "logits")]
    plan += [("calib_probe", n, None, None, f"calib_probe_{n}") for n in cfg.calib_buckets]
    if cfg.emit_splits:
        # Front halves split at every layer boundary m (Fig. 4 sweep); the
        # m == mid split is identical to prefill_front and skipped.
        for m in range(1, cfg.n_layers):
            if m == cfg.mid_layer:
                continue
            for n in cfg.prefill_buckets:
                plan.append(("frontsplit", n, m, None, f"frontsplit{m}_{n}"))

    for entry, n, split, batch, stem in plan:
        path = os.path.join(out_dir, f"{stem}.hlo.txt")
        if lower_entry(cfg, entry, n, use_pallas, path, force, split=split, batch=batch):
            built += 1
            print(f"  lowered {cfg.name}/{stem}", flush=True)

    meta = {
        "config": cfg.to_json_dict(),
        "impl": "pallas" if use_pallas else "jnp",
        "weights_dir": WEIGHT_ALIASES.get(cfg.name, cfg.name),
        "abi": {
            "prefill_front": abi_of(cfg, "prefill_front", cfg.prefill_buckets[0]),
            "back_layer": abi_of(cfg, "back_layer", cfg.seq_buckets[0]),
            "decode_layer": abi_of(cfg, "decode_layer", cfg.seq_buckets[0]),
            "decode_layer_batched": abi_of(
                cfg, "decode_layer_batched", cfg.seq_buckets[0],
                batch=cfg.batch_buckets[0],
            ) if cfg.batch_buckets else [],
            "logits": abi_of(cfg, "logits", 0),
            "calib_probe": abi_of(cfg, "calib_probe", cfg.calib_buckets[0]),
        },
    }
    with open(os.path.join(out_dir, "model.json"), "w") as f:
        json.dump(meta, f, indent=1)
    return built


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--model", default="all")
    ap.add_argument("--impl", default="pallas", choices=["pallas", "jnp"])
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args()

    names = list(CONFIGS) if args.model == "all" else [args.model]
    total = 0
    for name in names:
        total += build_model(CONFIGS[name], args.out, args.impl == "pallas", args.force)
    print(f"aot: {total} artifacts lowered (impl={args.impl})")


if __name__ == "__main__":
    main()
