"""AOT lowering: every L2 entry point → HLO *text* artifacts for rust/PJRT.

HLO text (not ``.serialize()``) is the interchange format: jax ≥ 0.5 emits
HloModuleProtos with 64-bit instruction ids that the xla crate's
xla_extension 0.5.1 rejects; the text parser reassigns ids and round-trips
cleanly (see /opt/xla-example/README.md).

Per model config this emits into ``artifacts/<model>/``:
  * ``prefill_front_<n>.hlo.txt``  (one per prefill bucket)
  * ``back_layer_<n>.hlo.txt``     (one per seq bucket)
  * ``decode_layer_<n>.hlo.txt``   (one per seq bucket)
  * ``decode_batch<b>_<n>.hlo.txt`` (one per batch bucket × seq bucket —
    the fused continuous-batching decode step)
  * ``logits.hlo.txt``
  * ``logits_batch_<b>.hlo.txt``   (one per batch bucket — the ``[B, d]``
    logits head closing a fused decode quantum)
  * ``calib_probe_<n>.hlo.txt``    (one per calib bucket)
  * ``model.json``                 (config + bucket grid + per-entry ABI
    + the ``mesh`` block documenting the tensor-parallel shard naming)

When ``cfg.tp_degree == D > 1``, the head-sharded mesh set is emitted on
top (shard ``s`` owns heads ``[s*H/D, (s+1)*H/D)``; ``*_tail`` is the
host-side combine's single unsharded stage):
  * ``layer_shard<s>of<D>_<n>.hlo.txt`` + ``layer_tail_<n>.hlo.txt``
    (prefill-shaped; one per seq∪prefill bucket — the mesh backend runs
    the front half per layer through these)
  * ``decode_shard<s>of<D>_<n>.hlo.txt`` + ``decode_tail.hlo.txt``
  * ``decode_batch<b>_shard<s>of<D>_<n>.hlo.txt`` +
    ``decode_batch_tail_<b>.hlo.txt``
  * ``logits_shard<s>of<D>.hlo.txt`` /
    ``logits_batch_shard<s>of<D>_<b>.hlo.txt`` (vocab partials, summed
    host-side)

Usage: python -m compile.aot [--out ../artifacts] [--model all]
       [--impl pallas|jnp] [--force]
"""

import argparse
import functools
import json
import os
import shutil

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from .config import CONFIGS, WEIGHT_ALIASES
from . import model as M


def to_hlo_text(lowered) -> str:
    """stablehlo → XlaComputation → HLO text (the 0.5.1-compatible path)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def spec(shape, dtype=jnp.float32):
    return jax.ShapeDtypeStruct(shape, dtype)


def layer_param_specs(cfg, stack=None):
    """ShapeDtypeStructs for the 9 per-layer params (optionally stacked)."""
    d, ff = cfg.d_model, cfg.d_ff
    shapes = {
        "ln1": (d,), "wq": (d, d), "wk": (d, d), "wv": (d, d), "wo": (d, d),
        "ln2": (d,), "wg": (d, ff), "wu": (d, ff), "wd": (ff, d),
    }
    out = []
    for name in M.LAYER_PARAM_NAMES:
        s = shapes[name]
        if stack is not None:
            s = (stack,) + s
        out.append(spec(s))
    return out


def shard_qkv_specs(cfg, tp):
    """ln1 + the QKV column slices a head-shard artifact takes.

    Shard ``s`` of ``tp`` owns ``H/tp`` heads — columns
    ``[s·d/tp, (s+1)·d/tp)`` of wq/wk/wv. Shapes are shard-independent.
    """
    d = cfg.d_model
    dc = d // tp
    return [spec((d,)), spec((d, dc)), spec((d, dc)), spec((d, dc))]


def tail_param_specs(cfg):
    """The 5 combine-stage params (wo, ln2, wg, wu, wd)."""
    d, ff = cfg.d_model, cfg.d_ff
    return [spec((d, d)), spec((d,)), spec((d, ff)), spec((d, ff)),
            spec((ff, d))]


def entry_specs(cfg, entry, n, split=None, batch=None, tp=None):
    """Input ShapeDtypeStructs for an entry point at bucket n (the rust ABI).

    ``split`` overrides the front-half depth for ``frontsplit`` artifacts
    (the Fig. 4 pruning-start-layer sweep); ``batch`` is the batch bucket
    for batched artifacts; ``tp`` is the shard count for ``*_shard``
    artifacts (defaults to ``cfg.tp_degree``).
    """
    d, h, dh = cfg.d_model, cfg.n_heads, cfg.d_head
    tp = cfg.tp_degree if tp is None else tp
    hs = h // tp
    if entry in ("prefill_front", "frontsplit"):
        stack = cfg.mid_layer if split is None else split
        return [spec((n, d)), spec((n,)), spec((n,), jnp.int32)] + \
            layer_param_specs(cfg, stack=stack)
    if entry == "back_layer":
        return [spec((n, d)), spec((n,)), spec((n,), jnp.int32),
                spec((), jnp.int32)] + layer_param_specs(cfg)
    if entry == "decode_layer":
        return [spec((d,)), spec((), jnp.int32), spec((), jnp.int32),
                spec((h, n, dh)), spec((h, n, dh)), spec((n,))] + \
            layer_param_specs(cfg)
    if entry == "decode_layer_batched":
        b = cfg.batch_buckets[0] if batch is None else batch
        return [spec((b, d)), spec((b,), jnp.int32), spec((b,), jnp.int32),
                spec((b, h, n, dh)), spec((b, h, n, dh)), spec((b, n))] + \
            layer_param_specs(cfg)
    if entry == "layer_shard":
        return [spec((n, d)), spec((n,)), spec((n,), jnp.int32),
                spec((), jnp.int32)] + shard_qkv_specs(cfg, tp)
    if entry == "layer_tail":
        return [spec((n, d)), spec((n, d)), spec((n,))] + tail_param_specs(cfg)
    if entry == "decode_shard":
        return [spec((d,)), spec((), jnp.int32), spec((), jnp.int32),
                spec((hs, n, dh)), spec((hs, n, dh)), spec((n,))] + \
            shard_qkv_specs(cfg, tp)
    if entry == "decode_tail":
        return [spec((d,)), spec((d,))] + tail_param_specs(cfg)
    if entry == "decode_shard_batched":
        b = cfg.batch_buckets[0] if batch is None else batch
        return [spec((b, d)), spec((b,), jnp.int32), spec((b,), jnp.int32),
                spec((b, hs, n, dh)), spec((b, hs, n, dh)), spec((b, n))] + \
            shard_qkv_specs(cfg, tp)
    if entry == "decode_batch_tail":
        b = cfg.batch_buckets[0] if batch is None else batch
        return [spec((b, d)), spec((b, d))] + tail_param_specs(cfg)
    if entry == "logits":
        return [spec((d,)), spec((d,)), spec((cfg.vocab, d))]
    if entry == "logits_batch":
        b = cfg.batch_buckets[0] if batch is None else batch
        return [spec((b, d)), spec((d,)), spec((cfg.vocab, d))]
    if entry == "logits_shard":
        return [spec((d,)), spec((d,)), spec((cfg.vocab, d // tp))]
    if entry == "logits_batch_shard":
        b = cfg.batch_buckets[0] if batch is None else batch
        return [spec((b, d)), spec((d,)), spec((cfg.vocab, d // tp))]
    if entry == "calib_probe":
        return [spec((n, d)), spec((n,)), spec((n,), jnp.int32)] + \
            layer_param_specs(cfg, stack=cfg.n_layers)
    raise ValueError(entry)


def entry_fn(cfg, entry, use_pallas, tp=None, shard=None):
    tp = cfg.tp_degree if tp is None else tp
    if entry in ("prefill_front", "frontsplit"):
        return functools.partial(M.prefill_front, cfg, use_pallas)
    if entry == "back_layer":
        return functools.partial(M.back_layer, cfg, use_pallas)
    if entry == "decode_layer":
        return functools.partial(M.decode_layer, cfg, use_pallas)
    if entry == "decode_layer_batched":
        return functools.partial(M.decode_layer_batched, cfg, use_pallas)
    if entry == "layer_shard":
        return functools.partial(M.layer_shard, cfg, use_pallas)
    if entry == "layer_tail":
        return functools.partial(M.layer_tail, cfg)
    if entry == "decode_shard":
        return functools.partial(M.decode_shard, cfg, use_pallas)
    if entry == "decode_tail":
        return functools.partial(M.decode_tail, cfg)
    if entry == "decode_shard_batched":
        return functools.partial(M.decode_shard_batched, cfg, use_pallas)
    if entry == "decode_batch_tail":
        return functools.partial(M.decode_tail_batched, cfg)
    if entry == "logits":
        return functools.partial(M.logits_head, cfg)
    if entry == "logits_batch":
        return functools.partial(M.logits_head_batched, cfg)
    if entry == "logits_shard":
        return functools.partial(M.logits_shard, cfg, tp, shard)
    if entry == "logits_batch_shard":
        return functools.partial(M.logits_shard_batched, cfg, tp, shard)
    if entry == "calib_probe":
        return functools.partial(M.calib_probe, cfg)
    raise ValueError(entry)


def lower_entry(cfg, entry, n, use_pallas, out_path, force, split=None,
                batch=None, tp=None, shard=None):
    if os.path.exists(out_path) and not force:
        return False
    specs = entry_specs(cfg, entry, n, split=split, batch=batch, tp=tp)
    fn = entry_fn(cfg, entry, use_pallas, tp=tp, shard=shard)
    lowered = jax.jit(fn).lower(*specs)
    text = to_hlo_text(lowered)
    with open(out_path, "w") as f:
        f.write(text)
    return True


def abi_of(cfg, entry, n, batch=None, tp=None):
    return [
        {"shape": list(s.shape), "dtype": str(s.dtype)}
        for s in entry_specs(cfg, entry, n, batch=batch, tp=tp)
    ]


def build_model(cfg, out_root, use_pallas, force):
    out_dir = os.path.join(out_root, cfg.name)
    os.makedirs(out_dir, exist_ok=True)
    built = 0

    # (entry, bucket, split, batch, shard, filename-stem)
    plan = [("prefill_front", n, None, None, None, f"prefill_front_{n}")
            for n in cfg.prefill_buckets]
    plan += [("back_layer", n, None, None, None, f"back_layer_{n}") for n in cfg.seq_buckets]
    plan += [("decode_layer", n, None, None, None, f"decode_layer_{n}") for n in cfg.seq_buckets]
    # Batched decode: one artifact per (batch bucket × seq bucket); the
    # rust engine picks the smallest (B, cap) pair covering a quantum's
    # decode-ready set and falls back to decode_layer when none fits.
    plan += [("decode_layer_batched", n, None, b, None, f"decode_batch{b}_{n}")
             for b in cfg.batch_buckets for n in cfg.seq_buckets]
    plan += [("logits", 0, None, None, None, "logits")]
    # Batched logits head: one dispatch closes a whole fused decode
    # quantum (replaces B single-vector logits dispatches).
    plan += [("logits_batch", 0, None, b, None, f"logits_batch_{b}")
             for b in cfg.batch_buckets]
    plan += [("calib_probe", n, None, None, None, f"calib_probe_{n}")
             for n in cfg.calib_buckets]
    if cfg.emit_splits:
        # Front halves split at every layer boundary m (Fig. 4 sweep); the
        # m == mid split is identical to prefill_front and skipped.
        for m in range(1, cfg.n_layers):
            if m == cfg.mid_layer:
                continue
            for n in cfg.prefill_buckets:
                plan.append(("frontsplit", n, m, None, None, f"frontsplit{m}_{n}"))
    if cfg.tp_degree > 1:
        # Head-sharded mesh set (see module docstring). layer_shard serves
        # both front layers (per-layer prefill on the mesh path) and back
        # layers, so it is lowered at the union of the bucket grids.
        # Only the logits shards depend on the shard index (the hidden
        # slice is baked in); layer/decode shard bodies are identical
        # across shards — shard 0 is lowered and shards 1.. are file
        # copies below, keeping jit work O(1) in D for those entries.
        tp = cfg.tp_degree
        layer_buckets = sorted(set(cfg.seq_buckets) | set(cfg.prefill_buckets))
        plan += [("layer_shard", n, None, None, 0, f"layer_shard0of{tp}_{n}")
                 for n in layer_buckets]
        plan += [("decode_shard", n, None, None, 0, f"decode_shard0of{tp}_{n}")
                 for n in cfg.seq_buckets]
        plan += [("decode_shard_batched", n, None, b, 0,
                  f"decode_batch{b}_shard0of{tp}_{n}")
                 for b in cfg.batch_buckets for n in cfg.seq_buckets]
        for s in range(tp):
            plan += [("logits_shard", 0, None, None, s, f"logits_shard{s}of{tp}")]
            plan += [("logits_batch_shard", 0, None, b, s,
                      f"logits_batch_shard{s}of{tp}_{b}")
                     for b in cfg.batch_buckets]
        plan += [("layer_tail", n, None, None, None, f"layer_tail_{n}")
                 for n in layer_buckets]
        plan += [("decode_tail", 0, None, None, None, "decode_tail")]
        plan += [("decode_batch_tail", 0, None, b, None, f"decode_batch_tail_{b}")
                 for b in cfg.batch_buckets]

    for entry, n, split, batch, shard, stem in plan:
        path = os.path.join(out_dir, f"{stem}.hlo.txt")
        if lower_entry(cfg, entry, n, use_pallas, path, force, split=split,
                       batch=batch, shard=shard):
            built += 1
            print(f"  lowered {cfg.name}/{stem}", flush=True)

    if cfg.tp_degree > 1:
        # Fan shard 0's shard-independent artifacts out to shards 1..
        # (the head range lives in the weight slices fed at execution
        # time, not in the lowered HLO — the rust mesh compiles each
        # file on its own device regardless).
        tp = cfg.tp_degree
        stems0 = [f"layer_shard0of{tp}_{n}" for n in layer_buckets]
        stems0 += [f"decode_shard0of{tp}_{n}" for n in cfg.seq_buckets]
        stems0 += [f"decode_batch{b}_shard0of{tp}_{n}"
                   for b in cfg.batch_buckets for n in cfg.seq_buckets]
        for stem0 in stems0:
            src = os.path.join(out_dir, f"{stem0}.hlo.txt")
            if not os.path.exists(src):
                continue
            for s in range(1, tp):
                stem_s = stem0.replace("shard0of", f"shard{s}of")
                dst = os.path.join(out_dir, f"{stem_s}.hlo.txt")
                if force or not os.path.exists(dst):
                    shutil.copyfile(src, dst)
                    built += 1
                    print(f"  copied  {cfg.name}/{stem_s}", flush=True)

    abi = {
        "prefill_front": abi_of(cfg, "prefill_front", cfg.prefill_buckets[0]),
        "back_layer": abi_of(cfg, "back_layer", cfg.seq_buckets[0]),
        "decode_layer": abi_of(cfg, "decode_layer", cfg.seq_buckets[0]),
        "decode_layer_batched": abi_of(
            cfg, "decode_layer_batched", cfg.seq_buckets[0],
            batch=cfg.batch_buckets[0],
        ) if cfg.batch_buckets else [],
        "logits": abi_of(cfg, "logits", 0),
        "logits_batch": abi_of(
            cfg, "logits_batch", 0, batch=cfg.batch_buckets[0],
        ) if cfg.batch_buckets else [],
        "calib_probe": abi_of(cfg, "calib_probe", cfg.calib_buckets[0]),
    }
    if cfg.tp_degree > 1:
        abi["layer_shard"] = abi_of(cfg, "layer_shard", cfg.seq_buckets[0])
        abi["layer_tail"] = abi_of(cfg, "layer_tail", cfg.seq_buckets[0])
        abi["decode_shard"] = abi_of(cfg, "decode_shard", cfg.seq_buckets[0])
        abi["decode_tail"] = abi_of(cfg, "decode_tail", 0)
        abi["logits_shard"] = abi_of(cfg, "logits_shard", 0)
        if cfg.batch_buckets:
            abi["decode_shard_batched"] = abi_of(
                cfg, "decode_shard_batched", cfg.seq_buckets[0],
                batch=cfg.batch_buckets[0])
            abi["decode_batch_tail"] = abi_of(
                cfg, "decode_batch_tail", 0, batch=cfg.batch_buckets[0])
            abi["logits_batch_shard"] = abi_of(
                cfg, "logits_batch_shard", 0, batch=cfg.batch_buckets[0])

    meta = {
        "config": cfg.to_json_dict(),
        "impl": "pallas" if use_pallas else "jnp",
        "weights_dir": WEIGHT_ALIASES.get(cfg.name, cfg.name),
        # The device-mesh ABI contract the rust backend executes against.
        "mesh": {
            "tp_degree": cfg.tp_degree,
            "shard_axis": "attention heads (H/D per device; logits shard "
                          "d_model columns of the tied unembedding)",
            "naming": "layer_shard<s>of<D>_<n> / decode_shard<s>of<D>_<n> / "
                      "decode_batch<b>_shard<s>of<D>_<n> / logits_shard<s>of<D> / "
                      "logits_batch_shard<s>of<D>_<b>; combine stages "
                      "layer_tail_<n> / decode_tail / decode_batch_tail_<b>. "
                      "Shard s owns heads [s*H/D, (s+1)*H/D); the host "
                      "concatenates attention outputs in head order, sums "
                      "logits partials, and sums importance partials.",
        },
        "abi": abi,
    }
    with open(os.path.join(out_dir, "model.json"), "w") as f:
        json.dump(meta, f, indent=1)
    return built


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--model", default="all")
    ap.add_argument("--impl", default="pallas", choices=["pallas", "jnp"])
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args()

    names = list(CONFIGS) if args.model == "all" else [args.model]
    total = 0
    for name in names:
        total += build_model(CONFIGS[name], args.out, args.impl == "pallas", args.force)
    print(f"aot: {total} artifacts lowered (impl={args.impl})")


if __name__ == "__main__":
    main()
