"""Weights export: ``weights.bin`` + ``manifest.json`` (rust ABI).

Format (consumed by ``rust/src/model/weights.rs``):
  * ``weights.bin`` — raw little-endian float32, tensors concatenated in
    manifest order, no alignment padding (f32 elements are 4-aligned by
    construction).
  * ``manifest.json`` — ``{"tensors": [{"name", "shape", "offset"}...]}``
    with ``offset`` in *elements* from the start of the file.

Stacked per-layer tensors keep their leading ``[L, ...]`` axis so the rust
side can slice layer ``l`` (or the contiguous ``[0..mid)`` slab for the
fused front-half artifact) without copying.
"""

import json
import os

import numpy as np


TENSOR_ORDER = (
    "emb",
    "ln_f",
    "layers.ln1",
    "layers.wq",
    "layers.wk",
    "layers.wv",
    "layers.wo",
    "layers.ln2",
    "layers.wg",
    "layers.wu",
    "layers.wd",
)


def flatten_params(params):
    """Parameter pytree -> ordered {name: np.ndarray} dict."""
    out = {}
    for name in TENSOR_ORDER:
        if name.startswith("layers."):
            arr = params["layers"][name.split(".", 1)[1]]
        else:
            arr = params[name]
        out[name] = np.asarray(arr, dtype=np.float32)
    return out


def save_weights(params, out_dir):
    """Write weights.bin + manifest.json into ``out_dir``."""
    os.makedirs(out_dir, exist_ok=True)
    tensors = flatten_params(params)
    manifest = {"tensors": []}
    offset = 0
    with open(os.path.join(out_dir, "weights.bin"), "wb") as f:
        for name, arr in tensors.items():
            f.write(arr.tobytes())
            manifest["tensors"].append(
                {"name": name, "shape": list(arr.shape), "offset": offset}
            )
            offset += arr.size
    manifest["total_elements"] = offset
    with open(os.path.join(out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)


def load_weights(out_dir, cfg):
    """Read weights.bin back into the parameter pytree (round-trip tests,
    and reuse of trained weights by alias configs)."""
    with open(os.path.join(out_dir, "manifest.json")) as f:
        manifest = json.load(f)
    data = np.fromfile(os.path.join(out_dir, "weights.bin"), dtype=np.float32)
    params = {"layers": {}}
    for t in manifest["tensors"]:
        arr = data[t["offset"]:t["offset"] + int(np.prod(t["shape"]))].reshape(t["shape"])
        if t["name"].startswith("layers."):
            params["layers"][t["name"].split(".", 1)[1]] = arr
        else:
            params[t["name"]] = arr
    return params
