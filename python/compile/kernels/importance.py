"""Last-query token-importance Pallas kernels (the paper's hot spot).

FastAV's fine pruning (paper Eq. 4) scores every remaining token with
``s = mean_h softmax(Q_last K^T)`` — one softmax *row*, never the full
attention map. These kernels compute that row with a streaming
(two-accumulator online) softmax over key tiles, plus a fused decode
variant that also produces the attention output for the current token so
the serving path gets importance for free at decode time.

TPU mapping: a single query row is DMA-bound — arithmetic intensity
~2 FLOPs/byte of K — so the kernel shape is one (dh)·(dh x bk) VREG loop
per head streaming K tiles; see DESIGN.md §9 for roofline estimates.
``interpret=True`` mandatory on this image (CPU PJRT).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .attention import pick_block
from .ref import NEG_INF


def _importance_kernel(q_ref, k_ref, mask_ref, s_ref, *, bk, n):
    """Per-head streaming softmax row. Grid: (H,). Outputs per-head probs."""
    dh = q_ref.shape[-1]
    scale = 1.0 / jnp.sqrt(jnp.float32(dh))
    q = q_ref[0, :].astype(jnp.float32) * scale  # [dh]

    num_kb = n // bk

    # Pass 1: running max + denominator.
    def stats(kb, carry):
        m_i, l_i = carry
        k_tile = k_ref[0, pl.ds(kb * bk, bk), :].astype(jnp.float32)
        mask_tile = mask_ref[pl.ds(kb * bk, bk)]
        s = k_tile @ q + jnp.where(mask_tile > 0.5, 0.0, NEG_INF)  # [bk]
        m_new = jnp.maximum(m_i, jnp.maximum(jnp.max(s), NEG_INF / 2))
        l_new = l_i * jnp.exp(m_i - m_new) + jnp.sum(jnp.exp(s - m_new))
        return m_new, l_new

    m_i, l_i = jax.lax.fori_loop(0, num_kb, stats, (jnp.float32(NEG_INF), jnp.float32(0.0)))
    denom = jnp.maximum(l_i, 1e-30)

    # Pass 2: normalized probabilities written tile by tile.
    def write(kb, _):
        k_tile = k_ref[0, pl.ds(kb * bk, bk), :].astype(jnp.float32)
        mask_tile = mask_ref[pl.ds(kb * bk, bk)]
        s = k_tile @ q + jnp.where(mask_tile > 0.5, 0.0, NEG_INF)
        p = jnp.exp(s - m_i) / denom * mask_tile
        s_ref[0, pl.ds(kb * bk, bk)] = p.astype(s_ref.dtype)
        return 0

    jax.lax.fori_loop(0, num_kb, write, 0)


def importance_scores(q_last, k, mask, block_k=None):
    """Token importance via the Pallas kernel (paper Eq. 4).

    Args:
      q_last: ``[H, dh]`` last query row (post-RoPE).
      k: ``[H, n, dh]`` keys.
      mask: ``[n]`` validity mask.
      block_k: key tile size; default ``min(n, 128)``; must divide n.

    Returns:
      ``[n]`` head-averaged importance (identical to ``ref.ref_importance``).
    """
    h, n, dh = k.shape
    bk = block_k or pick_block(n)
    assert n % bk == 0, (n, bk)
    kernel = functools.partial(_importance_kernel, bk=bk, n=n)
    per_head = pl.pallas_call(
        kernel,
        grid=(h,),
        in_specs=[
            pl.BlockSpec((1, dh), lambda hh: (hh, 0)),
            pl.BlockSpec((1, n, dh), lambda hh: (hh, 0, 0)),
            pl.BlockSpec((n,), lambda hh: (0,)),
        ],
        out_specs=pl.BlockSpec((1, n), lambda hh: (hh, 0)),
        out_shape=jax.ShapeDtypeStruct((h, n), jnp.float32),
        interpret=True,
    )(q_last, k, mask)
    return jnp.mean(per_head, axis=0)


def _decode_kernel(q_ref, k_ref, v_ref, mask_ref, o_ref, s_ref, *, bk, n):
    """Fused decode-step attention: output vector + importance row."""
    dh = q_ref.shape[-1]
    scale = 1.0 / jnp.sqrt(jnp.float32(dh))
    q = q_ref[0, :].astype(jnp.float32) * scale

    num_kb = n // bk

    def body(kb, carry):
        m_i, l_i, acc = carry
        k_tile = k_ref[0, pl.ds(kb * bk, bk), :].astype(jnp.float32)
        v_tile = v_ref[0, pl.ds(kb * bk, bk), :].astype(jnp.float32)
        mask_tile = mask_ref[pl.ds(kb * bk, bk)]
        s = k_tile @ q + jnp.where(mask_tile > 0.5, 0.0, NEG_INF)
        m_new = jnp.maximum(m_i, jnp.maximum(jnp.max(s), NEG_INF / 2))
        alpha = jnp.exp(m_i - m_new)
        p = jnp.exp(s - m_new)
        l_new = l_i * alpha + jnp.sum(p)
        acc_new = acc * alpha + p @ v_tile
        return m_new, l_new, acc_new

    m_i, l_i, acc = jax.lax.fori_loop(
        0, num_kb, body, (jnp.float32(NEG_INF), jnp.float32(0.0), jnp.zeros((dh,), jnp.float32))
    )
    denom = jnp.maximum(l_i, 1e-30)
    o_ref[0, :] = (acc / denom).astype(o_ref.dtype)

    def write(kb, _):
        k_tile = k_ref[0, pl.ds(kb * bk, bk), :].astype(jnp.float32)
        mask_tile = mask_ref[pl.ds(kb * bk, bk)]
        s = k_tile @ q + jnp.where(mask_tile > 0.5, 0.0, NEG_INF)
        p = jnp.exp(s - m_i) / denom * mask_tile
        s_ref[0, pl.ds(kb * bk, bk)] = p.astype(s_ref.dtype)
        return 0

    jax.lax.fori_loop(0, num_kb, write, 0)


def decode_attention(q1, k, v, mask, block_k=None):
    """Fused single-query attention + importance (decode hot path).

    Args:
      q1: ``[H, dh]`` current decode query (post-RoPE).
      k, v: ``[H, n, dh]`` caches including the current token's K/V.
      mask: ``[n]`` validity mask.
      block_k: key tile size; default ``min(n, 128)``; must divide n.

    Returns:
      ``(out, s)`` — out ``[H, dh]``, s ``[n]`` head-averaged importance.
      Matches ``ref.ref_decode_attention``.
    """
    h, n, dh = k.shape
    bk = block_k or pick_block(n)
    assert n % bk == 0, (n, bk)
    kernel = functools.partial(_decode_kernel, bk=bk, n=n)
    out, per_head = pl.pallas_call(
        kernel,
        grid=(h,),
        in_specs=[
            pl.BlockSpec((1, dh), lambda hh: (hh, 0)),
            pl.BlockSpec((1, n, dh), lambda hh: (hh, 0, 0)),
            pl.BlockSpec((1, n, dh), lambda hh: (hh, 0, 0)),
            pl.BlockSpec((n,), lambda hh: (0,)),
        ],
        out_specs=[
            pl.BlockSpec((1, dh), lambda hh: (hh, 0)),
            pl.BlockSpec((1, n), lambda hh: (hh, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((h, dh), jnp.float32),
            jax.ShapeDtypeStruct((h, n), jnp.float32),
        ],
        interpret=True,
    )(q1, k, v, mask)
    return out, jnp.mean(per_head, axis=0)
