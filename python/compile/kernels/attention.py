"""Fused causal multi-head attention as a Pallas kernel (L1 hot path).

Flash-style streaming softmax: the kernel walks key/value tiles and keeps
a running (max, denominator, weighted-accumulator) triple per query row,
so the full ``n x n`` attention map is never materialized — this is the
property FastAV relies on for FlashAttention compatibility (paper §1).

TPU mapping (see DESIGN.md §Hardware-Adaptation): the grid iterates
(head, query-block); each step streams K/V tiles HBM→VMEM via BlockSpec
and feeds (bq x dh)·(dh x bk) products to the MXU. ``interpret=True`` is
mandatory on this image — real-TPU lowering emits a Mosaic custom-call
the CPU PJRT plugin cannot execute; numerics are validated through the
interpret path against ``ref.py``.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .ref import NEG_INF


def pick_block(n, cap=128):
    """Largest tile size <= cap that divides n (buckets are multiples of 16,
    so 16 always qualifies)."""
    for b in (128, 96, 64, 48, 32, 16):
        if b <= cap and n % b == 0:
            return b
    return n  # tiny shapes: single block


def _attention_kernel(q_ref, k_ref, v_ref, mask_ref, o_ref, *, bq, bk, n, causal):
    """One (head, query-block) grid step of flash attention.

    Refs:
      q_ref: ``[1, bq, dh]`` query tile for this head/block.
      k_ref, v_ref: ``[1, n, dh]`` full K/V for this head (tiles are
        sliced inside the kernel with ``pl.ds`` so the softmax streams).
      mask_ref: ``[n]`` key validity mask.
      o_ref: ``[1, bq, dh]`` output tile.
    """
    qb = pl.program_id(1)
    dh = q_ref.shape[-1]
    scale = 1.0 / jnp.sqrt(jnp.float32(dh))
    q = q_ref[0, :, :].astype(jnp.float32) * scale  # [bq, dh]

    q_pos = qb * bq + jax.lax.iota(jnp.int32, bq)  # global query rows

    # Running statistics of the online softmax.
    m_i = jnp.full((bq,), NEG_INF, dtype=jnp.float32)
    l_i = jnp.zeros((bq,), dtype=jnp.float32)
    acc = jnp.zeros((bq, dh), dtype=jnp.float32)

    # Causal structure lets us stop at the tile containing the last query
    # row of this block. qb is a traced grid index, so clamp with jnp ops;
    # fori_loop with a traced bound lowers to while_loop.
    if causal:
        num_kb = jnp.clip((qb * bq + bq + bk - 1) // bk, 1, n // bk)
    else:
        num_kb = n // bk

    def body(kb, carry):
        m_i, l_i, acc = carry
        k_tile = k_ref[0, pl.ds(kb * bk, bk), :].astype(jnp.float32)  # [bk, dh]
        v_tile = v_ref[0, pl.ds(kb * bk, bk), :].astype(jnp.float32)
        mask_tile = mask_ref[pl.ds(kb * bk, bk)]

        s = q @ k_tile.T  # [bq, bk]
        k_pos = kb * bk + jax.lax.iota(jnp.int32, bk)
        bias = jnp.where(mask_tile[None, :] > 0.5, 0.0, NEG_INF)
        if causal:
            bias = bias + jnp.where(k_pos[None, :] <= q_pos[:, None], 0.0, NEG_INF)
        s = s + bias

        m_new = jnp.maximum(m_i, jnp.max(s, axis=-1))
        m_new = jnp.maximum(m_new, NEG_INF / 2)  # fully-masked row guard
        alpha = jnp.exp(m_i - m_new)
        p = jnp.exp(s - m_new[:, None])
        l_new = l_i * alpha + jnp.sum(p, axis=-1)
        acc_new = acc * alpha[:, None] + p @ v_tile
        return m_new, l_new, acc_new

    m_i, l_i, acc = jax.lax.fori_loop(0, num_kb, body, (m_i, l_i, acc))
    out = acc / jnp.maximum(l_i, 1e-30)[:, None]
    o_ref[0, :, :] = out.astype(o_ref.dtype)


def flash_attention(q, k, v, mask, causal=True, block_q=None, block_k=None):
    """Multi-head attention via the Pallas flash kernel.

    Args:
      q, k, v: ``[H, n, dh]`` float32 (post-RoPE).
      mask: ``[n]`` float32 key validity mask.
      causal: lower-triangular masking by row index.
      block_q / block_k: tile sizes; default ``min(n, 128)``. Must divide n.

    Returns:
      ``[H, n, dh]`` float32 attention output (identical semantics to
      ``ref.ref_attention``).
    """
    h, n, dh = q.shape
    bq = block_q or pick_block(n)
    bk = block_k or pick_block(n)
    assert n % bq == 0 and n % bk == 0, (n, bq, bk)

    kernel = functools.partial(_attention_kernel, bq=bq, bk=bk, n=n, causal=causal)
    return pl.pallas_call(
        kernel,
        grid=(h, n // bq),
        in_specs=[
            pl.BlockSpec((1, bq, dh), lambda hh, qq: (hh, qq, 0)),
            pl.BlockSpec((1, n, dh), lambda hh, qq: (hh, 0, 0)),
            pl.BlockSpec((1, n, dh), lambda hh, qq: (hh, 0, 0)),
            pl.BlockSpec((n,), lambda hh, qq: (0,)),
        ],
        out_specs=pl.BlockSpec((1, bq, dh), lambda hh, qq: (hh, qq, 0)),
        out_shape=jax.ShapeDtypeStruct((h, n, dh), q.dtype),
        interpret=True,
    )(q, k, v, mask)
