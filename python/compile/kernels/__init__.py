"""L1 Pallas kernels for FastAV: the paper's compute hot-spots.

Public surface:
  * :func:`attention.flash_attention`   — fused causal MHA (prefill).
  * :func:`importance.importance_scores` — last-query importance (Eq. 4).
  * :func:`importance.decode_attention` — fused decode attention + importance.
  * :func:`rollout.rollout_step`        — calibration rollout accumulation.
  * :mod:`ref`                          — pure-jnp oracles for all of the above.
"""

from .attention import flash_attention
from .importance import decode_attention, importance_scores
from .rollout import rollout_step
from . import ref

__all__ = [
    "flash_attention",
    "decode_attention",
    "importance_scores",
    "rollout_step",
    "ref",
]
