"""Attention-rollout accumulation step as a tiled Pallas matmul kernel.

Calibration path only (paper Eqs. 2–3): ``R^l = (a*A + (1-a)*I) @ R^{l-1}``.
The residual convex combination is fused into the matmul's left operand
tile-by-tile, so the intermediate ``A-tilde`` matrix is never allocated.

Grid: (rows/bm, cols/bn); the contraction dimension streams in ``bkk``
tiles inside the kernel. ``interpret=True`` mandatory on this image.
"""

import functools

from .attention import pick_block

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _rollout_kernel(a_ref, r_ref, o_ref, *, bm, bn, bkk, n, alpha):
    """One (row-block, col-block) output tile of R' = A_tilde @ R."""
    i = pl.program_id(0)
    acc = jnp.zeros((bm, bn), dtype=jnp.float32)

    row_pos = i * bm + jax.lax.iota(jnp.int32, bm)

    def body(kb, acc):
        a_tile = a_ref[0:bm, pl.ds(kb * bkk, bkk)].astype(jnp.float32)
        # Fuse A_tilde = alpha*A + (1-alpha)*I into the loaded tile.
        col_pos = kb * bkk + jax.lax.iota(jnp.int32, bkk)
        eye = (row_pos[:, None] == col_pos[None, :]).astype(jnp.float32)
        a_tile = alpha * a_tile + (1.0 - alpha) * eye
        r_tile = r_ref[pl.ds(kb * bkk, bkk), 0:bn].astype(jnp.float32)
        return acc + a_tile @ r_tile

    acc = jax.lax.fori_loop(0, n // bkk, body, acc)
    o_ref[:, :] = acc.astype(o_ref.dtype)


def rollout_step(a_bar, r, alpha, block=None):
    """One rollout accumulation step via the Pallas kernel.

    Args:
      a_bar: ``[n, n]`` head-averaged attention probabilities at layer l.
      r: ``[n, n]`` rollout through layer l-1.
      alpha: python float, residual/attention balance (baked at lowering).
      block: square tile size; default ``min(n, 128)``; must divide n.

    Returns:
      ``[n, n]`` updated rollout; matches ``ref.ref_rollout_step``.
    """
    n = a_bar.shape[0]
    b = block or pick_block(n)
    assert n % b == 0, (n, b)
    kernel = functools.partial(
        _rollout_kernel, bm=b, bn=b, bkk=b, n=n, alpha=float(alpha)
    )
    return pl.pallas_call(
        kernel,
        grid=(n // b, n // b),
        in_specs=[
            pl.BlockSpec((b, n), lambda ii, jj: (ii, 0)),
            pl.BlockSpec((n, b), lambda ii, jj: (0, jj)),
        ],
        out_specs=pl.BlockSpec((b, b), lambda ii, jj: (ii, jj)),
        out_shape=jax.ShapeDtypeStruct((n, n), a_bar.dtype),
        interpret=True,
    )(a_bar, r)
