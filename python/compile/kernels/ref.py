"""Pure-jnp reference oracles for the L1 Pallas kernels.

Every kernel in this package has an exact functional counterpart here.
pytest (``python/tests/test_kernels.py``) sweeps shapes/dtypes with
hypothesis and asserts ``assert_allclose(kernel(...), ref(...))`` — this
file is the single source of truth for kernel semantics.

Conventions (shared with the kernels and the L2 model):
  * ``q, k, v``     — ``[H, n, dh]`` float32, post-RoPE.
  * ``mask``        — ``[n]`` float32, 1.0 = valid token, 0.0 = padding.
  * causal masking  — query *i* may attend to keys ``j <= i`` (row index
    within the compacted sequence; RoPE phases carry the *original*
    positions separately).
  * ``NEG_INF``     — large negative bias, not actual ``-inf`` (keeps
    softmax NaN-free for fully-masked rows).
"""

import jax.numpy as jnp

NEG_INF = -1e30


def attention_bias(mask, n, causal):
    """Additive attention bias ``[n, n]`` from a validity mask.

    Combines key-padding and (optionally) causal structure. Rows are
    query positions, columns key positions.
    """
    bias = jnp.where(mask[None, :] > 0.5, 0.0, NEG_INF)
    if causal:
        q_idx = jnp.arange(n)[:, None]
        k_idx = jnp.arange(n)[None, :]
        bias = bias + jnp.where(k_idx <= q_idx, 0.0, NEG_INF)
    return bias


def ref_attention(q, k, v, mask, causal=True):
    """Reference multi-head scaled-dot-product attention.

    Args:
      q, k, v: ``[H, n, dh]`` float32.
      mask: ``[n]`` float32 validity mask over keys.
      causal: apply lower-triangular masking.

    Returns:
      ``[H, n, dh]`` attention output.
    """
    h, n, dh = q.shape
    scale = 1.0 / jnp.sqrt(jnp.float32(dh))
    logits = jnp.einsum("hqd,hkd->hqk", q, k) * scale
    logits = logits + attention_bias(mask, n, causal)[None, :, :]
    # Max-subtracted softmax; clamp so fully-masked rows stay finite.
    m = jnp.max(logits, axis=-1, keepdims=True)
    m = jnp.maximum(m, NEG_INF / 2)
    p = jnp.exp(logits - m)
    denom = jnp.sum(p, axis=-1, keepdims=True)
    p = p / jnp.maximum(denom, 1e-30)
    return jnp.einsum("hqk,hkd->hqd", p, v)


def ref_importance(q_last, k, mask):
    """Reference last-query token importance (paper Eq. 4).

    ``s = mean_h softmax(q_last K^T / sqrt(dh))`` over valid keys.

    Args:
      q_last: ``[H, dh]`` the last query row, post-RoPE.
      k: ``[H, n, dh]`` key features.
      mask: ``[n]`` validity mask.

    Returns:
      ``[n]`` importance scores; exactly 0 at padded positions.
    """
    h, n, dh = k.shape
    scale = 1.0 / jnp.sqrt(jnp.float32(dh))
    logits = jnp.einsum("hd,hkd->hk", q_last, k) * scale
    logits = logits + jnp.where(mask[None, :] > 0.5, 0.0, NEG_INF)
    m = jnp.maximum(jnp.max(logits, axis=-1, keepdims=True), NEG_INF / 2)
    p = jnp.exp(logits - m)
    p = p / jnp.maximum(jnp.sum(p, axis=-1, keepdims=True), 1e-30)
    s = jnp.mean(p, axis=0)
    return s * mask


def ref_decode_attention(q1, k, v, mask):
    """Reference single-query (decode-step) attention + importance row.

    Args:
      q1: ``[H, dh]`` the current decode query.
      k, v: ``[H, n, dh]`` cached keys/values (the query's own K/V must
        already be appended by the caller).
      mask: ``[n]`` validity mask.

    Returns:
      ``(out, s)`` where out is ``[H, dh]`` and s is ``[n]`` — the
      head-averaged attention row reused as the fine-pruning importance
      signal (paper §2.2: the last query's attention directly influences
      next-token prediction).
    """
    h, n, dh = k.shape
    scale = 1.0 / jnp.sqrt(jnp.float32(dh))
    logits = jnp.einsum("hd,hkd->hk", q1, k) * scale
    logits = logits + jnp.where(mask[None, :] > 0.5, 0.0, NEG_INF)
    m = jnp.maximum(jnp.max(logits, axis=-1, keepdims=True), NEG_INF / 2)
    p = jnp.exp(logits - m)
    p = p / jnp.maximum(jnp.sum(p, axis=-1, keepdims=True), 1e-30)
    out = jnp.einsum("hk,hkd->hd", p, v)
    return out, jnp.mean(p, axis=0) * mask


def ref_rollout_step(a_bar, r, alpha):
    """Reference attention-rollout accumulation step (paper Eqs. 2–3).

    ``R^l = (alpha * A^l + (1 - alpha) * I) @ R^{l-1}`` with the convex
    residual combination of the head-averaged attention matrix.

    Args:
      a_bar: ``[n, n]`` head-averaged attention probabilities at layer l.
      r: ``[n, n]`` rollout accumulated through layer l-1 (identity at l=0).
      alpha: residual/attention balance in [0, 1].

    Returns:
      ``[n, n]`` updated rollout.
    """
    n = a_bar.shape[0]
    a_tilde = alpha * a_bar + (1.0 - alpha) * jnp.eye(n, dtype=a_bar.dtype)
    return a_tilde @ r


def ref_attention_probs(q, k, mask, causal=True):
    """Head-averaged attention probability matrix ``[n, n]``.

    Calibration-path helper (offline only — the serving path never
    materializes this map). Rows are queries, columns keys.
    """
    h, n, dh = q.shape
    scale = 1.0 / jnp.sqrt(jnp.float32(dh))
    logits = jnp.einsum("hqd,hkd->hqk", q, k) * scale
    logits = logits + attention_bias(mask, n, causal)[None, :, :]
    m = jnp.maximum(jnp.max(logits, axis=-1, keepdims=True), NEG_INF / 2)
    p = jnp.exp(logits - m)
    p = p / jnp.maximum(jnp.sum(p, axis=-1, keepdims=True), 1e-30)
    return jnp.mean(p, axis=0)
