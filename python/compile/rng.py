"""SplitMix64 — deterministic RNG shared bit-exactly with the rust side.

The synthetic AV task generators exist twice: here (training data, L2) and
in ``rust/src/avsynth/`` (serving + evaluation). Both sides must produce
*identical* sample streams from the same seed, so both implement this exact
SplitMix64. ``python/tests/test_avsynth.py`` and rust's
``avsynth::tests::rng_reference_vectors`` pin the same reference vectors.
"""

MASK64 = (1 << 64) - 1


class SplitMix64:
    """SplitMix64 PRNG (Steele et al.); 64-bit state, 64-bit output."""

    def __init__(self, seed: int):
        self.state = seed & MASK64

    def next_u64(self) -> int:
        self.state = (self.state + 0x9E3779B97F4A7C15) & MASK64
        z = self.state
        z = ((z ^ (z >> 30)) * 0xBF58476D1CE4E5B9) & MASK64
        z = ((z ^ (z >> 27)) * 0x94D049BB133111EB) & MASK64
        return (z ^ (z >> 31)) & MASK64

    def next_below(self, n: int) -> int:
        """Uniform integer in [0, n) via 64-bit modulo (bias negligible,
        and — critically — identical on both implementations)."""
        assert n > 0
        return self.next_u64() % n

    def next_f64(self) -> float:
        """Uniform float in [0, 1) with 53 bits of entropy."""
        return (self.next_u64() >> 11) * (1.0 / (1 << 53))

    def chance(self, p: float) -> bool:
        return self.next_f64() < p


def derive_seed(base_seed: int, stream: int, index: int) -> int:
    """Per-(stream, sample) seed derivation — one SplitMix64 scramble of the
    mixed inputs so neighbouring indices decorrelate. Mirrored in rust."""
    mixer = SplitMix64((base_seed ^ (stream * 0x9E3779B97F4A7C15) ^ index) & MASK64)
    return mixer.next_u64()
