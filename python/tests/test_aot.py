"""AOT pipeline smoke tests: lowering emits parseable HLO text with the
expected entry computation, and the weights export round-trips.
"""

import dataclasses
import json
import os

import numpy as np
import jax
import pytest

from compile import aot
from compile.config import TINY
from compile.export import flatten_params, load_weights, save_weights
from compile.model import init_params

# The CI job matrix lowers with FASTAV_TEST_TP in {1, 2}; locally both
# degrees are also covered explicitly by the parametrized tests below.
MATRIX_TP = int(os.environ.get("FASTAV_TEST_TP", "2"))


def tiny_tp(tp):
    """TINY at an explicit tensor-parallel degree."""
    return dataclasses.replace(TINY, tp_degree=tp)


def test_entry_specs_shapes():
    specs = aot.entry_specs(TINY, "prefill_front", 32)
    assert specs[0].shape == (32, TINY.d_model)
    assert specs[1].shape == (32,)
    assert specs[2].shape == (32,)
    # 9 stacked layer params.
    assert len(specs) == 3 + 9
    assert specs[3].shape == (TINY.mid_layer, TINY.d_model)

    specs = aot.entry_specs(TINY, "decode_layer", 16)
    assert specs[3].shape == (TINY.n_heads, 16, TINY.d_head)
    assert len(specs) == 6 + 9


def test_entry_specs_batched_decode_shapes():
    b, n = TINY.batch_buckets[1], 16  # (4, 16)
    specs = aot.entry_specs(TINY, "decode_layer_batched", n, batch=b)
    assert specs[0].shape == (b, TINY.d_model)
    assert specs[1].shape == (b,) and str(specs[1].dtype) == "int32"
    assert specs[2].shape == (b,) and str(specs[2].dtype) == "int32"
    assert specs[3].shape == (b, TINY.n_heads, n, TINY.d_head)
    assert specs[4].shape == (b, TINY.n_heads, n, TINY.d_head)
    assert specs[5].shape == (b, n)
    assert len(specs) == 6 + 9
    # batch defaults to the first configured batch bucket.
    specs = aot.entry_specs(TINY, "decode_layer_batched", n)
    assert specs[0].shape == (TINY.batch_buckets[0], TINY.d_model)


def test_lower_batched_decode_produces_hlo(tmp_path):
    path = tmp_path / "decode_batch2_16.hlo.txt"
    assert aot.lower_entry(TINY, "decode_layer_batched", 16, True, str(path),
                           force=True, batch=2)
    text = path.read_text()
    assert "ENTRY" in text and "HloModule" in text


def test_abi_batched_decode_serializable():
    abi = aot.abi_of(TINY, "decode_layer_batched", 16, batch=TINY.batch_buckets[0])
    parsed = json.loads(json.dumps(abi))
    assert parsed[0]["shape"] == [TINY.batch_buckets[0], TINY.d_model]
    assert parsed[3]["shape"] == [TINY.batch_buckets[0], TINY.n_heads, 16, TINY.d_head]


def test_lower_back_layer_produces_hlo(tmp_path):
    path = tmp_path / "back_layer_16.hlo.txt"
    assert aot.lower_entry(TINY, "back_layer", 16, True, str(path), force=True)
    text = path.read_text()
    assert "ENTRY" in text and "HloModule" in text
    # Lowering again without force is a no-op.
    assert not aot.lower_entry(TINY, "back_layer", 16, True, str(path), force=False)


def test_lower_logits_produces_hlo(tmp_path):
    path = tmp_path / "logits.hlo.txt"
    assert aot.lower_entry(TINY, "logits", 0, False, str(path), force=True)
    assert "ENTRY" in path.read_text()


def test_abi_json_serializable():
    abi = aot.abi_of(TINY, "decode_layer", 16)
    txt = json.dumps(abi)
    parsed = json.loads(txt)
    assert parsed[0]["shape"] == [TINY.d_model]
    assert parsed[1]["dtype"] == "int32"


def test_entry_specs_sharded_shapes():
    """Mesh ABI shapes: shard inputs carry H/D heads and d/D QKV columns;
    tails take the concatenated [n, d] attention plus the 5 tail params."""
    cfg = tiny_tp(2)
    hs = cfg.n_heads // 2
    n = 16
    specs = aot.entry_specs(cfg, "layer_shard", n)
    assert specs[0].shape == (n, cfg.d_model)
    assert specs[3].shape == () and str(specs[3].dtype) == "int32"
    assert specs[4].shape == (cfg.d_model,)  # ln1
    assert specs[5].shape == (cfg.d_model, cfg.d_model // 2)  # wq slice
    assert len(specs) == 4 + 4

    specs = aot.entry_specs(cfg, "layer_tail", n)
    assert specs[0].shape == (n, cfg.d_model)
    assert specs[1].shape == (n, cfg.d_model)
    assert specs[3].shape == (cfg.d_model, cfg.d_model)  # wo
    assert len(specs) == 3 + 5

    specs = aot.entry_specs(cfg, "decode_shard", n)
    assert specs[3].shape == (hs, n, cfg.d_head)
    assert specs[4].shape == (hs, n, cfg.d_head)
    assert len(specs) == 6 + 4

    b = cfg.batch_buckets[0]
    specs = aot.entry_specs(cfg, "decode_shard_batched", n, batch=b)
    assert specs[3].shape == (b, hs, n, cfg.d_head)
    specs = aot.entry_specs(cfg, "decode_batch_tail", 0, batch=b)
    assert specs[0].shape == (b, cfg.d_model)
    assert specs[1].shape == (b, cfg.d_model)

    specs = aot.entry_specs(cfg, "logits_shard", 0)
    assert specs[2].shape == (cfg.vocab, cfg.d_model // 2)
    specs = aot.entry_specs(cfg, "logits_batch", 0, batch=b)
    assert specs[0].shape == (b, cfg.d_model)
    assert specs[2].shape == (cfg.vocab, cfg.d_model)
    specs = aot.entry_specs(cfg, "logits_batch_shard", 0, batch=b)
    assert specs[2].shape == (cfg.vocab, cfg.d_model // 2)


@pytest.mark.parametrize("tp", sorted({1, 2, MATRIX_TP}))
def test_build_plan_covers_tp_degree(tp, tmp_path, monkeypatch):
    """The build plan emits the sharded mesh set exactly when tp_degree>1
    (shard-index-independent entries are lowered once and fanned out to
    shards 1.. as file copies), and model.json carries the mesh block +
    shard ABIs (tp matrix job)."""
    cfg = tiny_tp(tp)
    stems = []

    def fake_lower(cfg_, entry, n, use_pallas, out_path, force,
                   split=None, batch=None, tp=None, shard=None):
        stems.append(os.path.basename(out_path))
        with open(out_path, "w") as f:
            f.write(f"HloModule fake_{entry}\n")
        return True

    monkeypatch.setattr(aot, "lower_entry", fake_lower)
    aot.build_model(cfg, str(tmp_path), use_pallas=False, force=False)
    out_dir = tmp_path / cfg.name
    names = set(stems)
    assert "decode_layer_16.hlo.txt" in names
    assert "logits_batch_2.hlo.txt" in names  # batched logits head always
    sharded = [s for s in names if "shard" in s or "tail" in s]
    if tp == 1:
        assert sharded == []
        assert not list(out_dir.glob("*shard*"))
    else:
        # Shard-independent bodies: lowered once (shard 0 only) ...
        assert f"layer_shard0of{tp}_16.hlo.txt" in names
        assert f"layer_shard0of{tp}_32.hlo.txt" in names  # prefill bucket
        assert f"decode_shard0of{tp}_16.hlo.txt" in names
        assert f"decode_batch2_shard0of{tp}_16.hlo.txt" in names
        assert f"layer_shard1of{tp}_16.hlo.txt" not in names, \
            "shard 1 must be a copy, not a second lowering"
        # ... and fanned out to every shard as identical files.
        for s in range(tp):
            for stem in (f"layer_shard{s}of{tp}_16", f"layer_shard{s}of{tp}_32",
                         f"decode_shard{s}of{tp}_16",
                         f"decode_batch2_shard{s}of{tp}_16"):
                path = out_dir / f"{stem}.hlo.txt"
                assert path.exists(), stem
                assert path.read_text() == \
                    (out_dir / f"{stem.replace(f'shard{s}of', 'shard0of')}.hlo.txt").read_text()
            # Logits shards bake the hidden slice in: one lowering per s.
            assert f"logits_shard{s}of{tp}.hlo.txt" in names
            assert f"logits_batch_shard{s}of{tp}_2.hlo.txt" in names
        assert "layer_tail_16.hlo.txt" in names
        assert "decode_tail.hlo.txt" in names
        assert "decode_batch_tail_2.hlo.txt" in names
    meta = json.loads((tmp_path / cfg.name / "model.json").read_text())
    assert meta["config"]["tp_degree"] == tp
    assert meta["mesh"]["tp_degree"] == tp
    assert "shard" in meta["mesh"]["naming"]
    if tp > 1:
        assert meta["abi"]["decode_shard"][3]["shape"] == \
            [cfg.n_heads // tp, 16, cfg.d_head]
        assert meta["abi"]["logits_shard"][2]["shape"] == \
            [cfg.vocab, cfg.d_model // tp]
    else:
        assert "decode_shard" not in meta["abi"]


def test_matrix_degree_end_to_end_lowering(tmp_path):
    """Real (jax.jit) end-to-end build at the CI matrix degree: the full
    plan for a single-bucket tiny variant at ``tp_degree = MATRIX_TP``.
    This is the test the tp matrix actually varies — tp=1 emits the fused
    set only, tp=2 adds the sharded mesh set — so each matrix job pins a
    different lowering surface."""
    cfg = dataclasses.replace(
        tiny_tp(MATRIX_TP),
        prefill_buckets=(16,),
        seq_buckets=(16,),
        calib_buckets=(16,),
        batch_buckets=(2,),
        emit_splits=False,
    )
    aot.build_model(cfg, str(tmp_path), use_pallas=False, force=True)
    out_dir = tmp_path / cfg.name
    emitted = {p.name for p in out_dir.glob("*.hlo.txt")}
    assert "decode_layer_16.hlo.txt" in emitted
    assert "logits_batch_2.hlo.txt" in emitted
    if MATRIX_TP == 1:
        assert not [n for n in emitted if "shard" in n or "tail" in n]
    else:
        tp = MATRIX_TP
        for s in range(tp):
            assert f"layer_shard{s}of{tp}_16.hlo.txt" in emitted
            assert f"decode_shard{s}of{tp}_16.hlo.txt" in emitted
            assert f"logits_shard{s}of{tp}.hlo.txt" in emitted
        assert "layer_tail_16.hlo.txt" in emitted
        assert "decode_tail.hlo.txt" in emitted
        assert "decode_batch_tail_2.hlo.txt" in emitted
    for name in sorted(emitted)[:3]:
        assert "HloModule" in (out_dir / name).read_text(), name
    meta = json.loads((out_dir / "model.json").read_text())
    assert meta["mesh"]["tp_degree"] == MATRIX_TP


def test_lower_sharded_entries_produce_hlo(tmp_path):
    """Shard + tail entries lower to parseable HLO text (smoke, one each
    at the matrix tp when sharded entries exist)."""
    tp = max(MATRIX_TP, 2)
    cfg = tiny_tp(tp)
    for entry, n, batch, stem in [
        ("layer_shard", 16, None, "layer_shard0of%d_16" % tp),
        ("layer_tail", 16, None, "layer_tail_16"),
        ("decode_shard", 16, None, "decode_shard0of%d_16" % tp),
        ("decode_tail", 0, None, "decode_tail"),
        ("logits_shard", 0, None, "logits_shard0of%d" % tp),
        ("logits_batch", 0, 2, "logits_batch_2"),
    ]:
        path = tmp_path / f"{stem}.hlo.txt"
        assert aot.lower_entry(cfg, entry, n, False, str(path), force=True,
                               batch=batch, shard=0)
        text = path.read_text()
        assert "ENTRY" in text and "HloModule" in text, stem


def test_weights_roundtrip(tmp_path):
    params = init_params(TINY, jax.random.PRNGKey(0))
    save_weights(params, str(tmp_path))
    loaded = load_weights(str(tmp_path), TINY)
    flat_a = flatten_params(params)
    flat_b = flatten_params(loaded)
    assert flat_a.keys() == flat_b.keys()
    for k in flat_a:
        np.testing.assert_array_equal(flat_a[k], flat_b[k])
    manifest = json.loads((tmp_path / "manifest.json").read_text())
    total = manifest["total_elements"]
    assert os.path.getsize(tmp_path / "weights.bin") == total * 4
    # Offsets are contiguous and ordered.
    offs = [t["offset"] for t in manifest["tensors"]]
    assert offs == sorted(offs) and offs[0] == 0
