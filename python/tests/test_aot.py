"""AOT pipeline smoke tests: lowering emits parseable HLO text with the
expected entry computation, and the weights export round-trips.
"""

import json
import os

import numpy as np
import jax
import pytest

from compile import aot
from compile.config import TINY
from compile.export import flatten_params, load_weights, save_weights
from compile.model import init_params


def test_entry_specs_shapes():
    specs = aot.entry_specs(TINY, "prefill_front", 32)
    assert specs[0].shape == (32, TINY.d_model)
    assert specs[1].shape == (32,)
    assert specs[2].shape == (32,)
    # 9 stacked layer params.
    assert len(specs) == 3 + 9
    assert specs[3].shape == (TINY.mid_layer, TINY.d_model)

    specs = aot.entry_specs(TINY, "decode_layer", 16)
    assert specs[3].shape == (TINY.n_heads, 16, TINY.d_head)
    assert len(specs) == 6 + 9


def test_entry_specs_batched_decode_shapes():
    b, n = TINY.batch_buckets[1], 16  # (4, 16)
    specs = aot.entry_specs(TINY, "decode_layer_batched", n, batch=b)
    assert specs[0].shape == (b, TINY.d_model)
    assert specs[1].shape == (b,) and str(specs[1].dtype) == "int32"
    assert specs[2].shape == (b,) and str(specs[2].dtype) == "int32"
    assert specs[3].shape == (b, TINY.n_heads, n, TINY.d_head)
    assert specs[4].shape == (b, TINY.n_heads, n, TINY.d_head)
    assert specs[5].shape == (b, n)
    assert len(specs) == 6 + 9
    # batch defaults to the first configured batch bucket.
    specs = aot.entry_specs(TINY, "decode_layer_batched", n)
    assert specs[0].shape == (TINY.batch_buckets[0], TINY.d_model)


def test_lower_batched_decode_produces_hlo(tmp_path):
    path = tmp_path / "decode_batch2_16.hlo.txt"
    assert aot.lower_entry(TINY, "decode_layer_batched", 16, True, str(path),
                           force=True, batch=2)
    text = path.read_text()
    assert "ENTRY" in text and "HloModule" in text


def test_abi_batched_decode_serializable():
    abi = aot.abi_of(TINY, "decode_layer_batched", 16, batch=TINY.batch_buckets[0])
    parsed = json.loads(json.dumps(abi))
    assert parsed[0]["shape"] == [TINY.batch_buckets[0], TINY.d_model]
    assert parsed[3]["shape"] == [TINY.batch_buckets[0], TINY.n_heads, 16, TINY.d_head]


def test_lower_back_layer_produces_hlo(tmp_path):
    path = tmp_path / "back_layer_16.hlo.txt"
    assert aot.lower_entry(TINY, "back_layer", 16, True, str(path), force=True)
    text = path.read_text()
    assert "ENTRY" in text and "HloModule" in text
    # Lowering again without force is a no-op.
    assert not aot.lower_entry(TINY, "back_layer", 16, True, str(path), force=False)


def test_lower_logits_produces_hlo(tmp_path):
    path = tmp_path / "logits.hlo.txt"
    assert aot.lower_entry(TINY, "logits", 0, False, str(path), force=True)
    assert "ENTRY" in path.read_text()


def test_abi_json_serializable():
    abi = aot.abi_of(TINY, "decode_layer", 16)
    txt = json.dumps(abi)
    parsed = json.loads(txt)
    assert parsed[0]["shape"] == [TINY.d_model]
    assert parsed[1]["dtype"] == "int32"


def test_weights_roundtrip(tmp_path):
    params = init_params(TINY, jax.random.PRNGKey(0))
    save_weights(params, str(tmp_path))
    loaded = load_weights(str(tmp_path), TINY)
    flat_a = flatten_params(params)
    flat_b = flatten_params(loaded)
    assert flat_a.keys() == flat_b.keys()
    for k in flat_a:
        np.testing.assert_array_equal(flat_a[k], flat_b[k])
    manifest = json.loads((tmp_path / "manifest.json").read_text())
    total = manifest["total_elements"]
    assert os.path.getsize(tmp_path / "weights.bin") == total * 4
    # Offsets are contiguous and ordered.
    offs = [t["offset"] for t in manifest["tensors"]]
    assert offs == sorted(offs) and offs[0] == 0
