"""L2 model correctness: the staged (prefill/back/decode) decomposition must
be numerically equivalent to the monolithic training forward, and pruning
(row gather + original positions) must equal masking.

These are the invariants the whole rust serving path rests on.
"""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from compile import avsynth
from compile.config import TINY
from compile.model import (
    back_layer,
    calib_probe,
    decode_layer,
    decode_layer_batched,
    decode_shard,
    decode_shard_batched,
    decode_tail,
    decode_tail_batched,
    init_params,
    layer_shard,
    layer_tail,
    logits_head,
    logits_head_batched,
    logits_shard,
    logits_shard_batched,
    prefill_front,
    train_forward,
)

CFG = TINY
N = CFG.prefill_buckets[0]  # 32


@pytest.fixture(scope="module")
def params():
    return init_params(CFG, jax.random.PRNGKey(42))


@pytest.fixture(scope="module")
def sample_tokens():
    s = avsynth.gen_sample(CFG.layout, "avqa", 3, 1234)
    return s


def front_params(params):
    return [params["layers"][k][: CFG.mid_layer] for k in
            ("ln1", "wq", "wk", "wv", "wo", "ln2", "wg", "wu", "wd")]


def layer_params(params, l):
    return [params["layers"][k][l] for k in
            ("ln1", "wq", "wk", "wv", "wo", "ln2", "wg", "wu", "wd")]


def staged_last_logits(params, tokens, use_pallas=False):
    """Run the staged pipeline (prefill_front -> back layers -> logits) and
    return the next-token logits at the last valid position."""
    klen = len(tokens)
    x = np.zeros((N, CFG.d_model), np.float32)
    x[:klen] = np.asarray(params["emb"])[tokens]
    mask = np.zeros((N,), np.float32)
    mask[:klen] = 1.0
    pos = np.arange(N, dtype=np.int32)

    h, ks, vs = prefill_front(CFG, use_pallas, jnp.asarray(x), jnp.asarray(mask),
                              jnp.asarray(pos), *front_params(params))
    for l in range(CFG.mid_layer, CFG.n_layers):
        h, k, v, s = back_layer(CFG, use_pallas, h, jnp.asarray(mask),
                                jnp.asarray(pos), jnp.int32(klen - 1),
                                *layer_params(params, l))
    logits = logits_head(CFG, h[klen - 1], params["ln_f"], params["emb"])
    return np.asarray(logits)


def monolithic_last_logits(params, tokens):
    n = len(tokens)
    toks = np.zeros((1, N), np.int32)
    toks[0, :n] = tokens
    mask = np.zeros((1, N), np.float32)
    mask[0, :n] = 1.0
    logits = train_forward(CFG, params, jnp.asarray(toks), jnp.asarray(mask))
    return np.asarray(logits)[0, n - 1]


def test_staged_equals_monolithic(params, sample_tokens):
    """prefill_front + back_layer chain + logits == train_forward."""
    tokens = sample_tokens.prompt
    staged = staged_last_logits(params, tokens, use_pallas=False)
    mono = monolithic_last_logits(params, tokens)
    np.testing.assert_allclose(staged, mono, atol=2e-4, rtol=2e-4)


def test_staged_pallas_equals_jnp(params, sample_tokens):
    """The pallas-kernel artifact path matches the jnp path."""
    tokens = sample_tokens.prompt
    a = staged_last_logits(params, tokens, use_pallas=True)
    b = staged_last_logits(params, tokens, use_pallas=False)
    np.testing.assert_allclose(a, b, atol=2e-4, rtol=2e-4)


def test_decode_step_equals_teacher_forced(params, sample_tokens):
    """Decoding one token via decode_layer over caches == monolithic forward
    over prompt+token. This validates the entire KV-cache/decode ABI."""
    tokens = list(sample_tokens.prompt)
    next_tok = sample_tokens.answer[0]
    klen = len(tokens)

    # Stage 1: staged prefill collecting per-layer K/V.
    x = np.zeros((N, CFG.d_model), np.float32)
    x[:klen] = np.asarray(params["emb"])[tokens]
    mask = np.zeros((N,), np.float32)
    mask[:klen] = 1.0
    pos = np.arange(N, dtype=np.int32)
    h, ks, vs = prefill_front(CFG, False, jnp.asarray(x), jnp.asarray(mask),
                              jnp.asarray(pos), *front_params(params))
    caches = [(np.asarray(ks[l]), np.asarray(vs[l])) for l in range(CFG.mid_layer)]
    for l in range(CFG.mid_layer, CFG.n_layers):
        h, k, v, s = back_layer(CFG, False, h, jnp.asarray(mask), jnp.asarray(pos),
                                jnp.int32(klen - 1), *layer_params(params, l))
        caches.append((np.asarray(k), np.asarray(v)))

    # Stage 2: decode the next token at slot klen.
    mask2 = mask.copy()
    mask2[klen] = 1.0
    xt = np.asarray(params["emb"])[next_tok]
    xcur = jnp.asarray(xt)
    for l in range(CFG.n_layers):
        kc, vc = caches[l]
        xcur, k_new, v_new, s = decode_layer(
            CFG, False, xcur, jnp.int32(klen), jnp.int32(klen),
            jnp.asarray(kc), jnp.asarray(vc), jnp.asarray(mask2),
            *layer_params(params, l))
    got = np.asarray(logits_head(CFG, xcur, params["ln_f"], params["emb"]))

    want = monolithic_last_logits(params, tokens + [next_tok])
    np.testing.assert_allclose(got, want, atol=3e-4, rtol=3e-4)


def test_batched_decode_equals_single(params, sample_tokens):
    """decode_layer_batched row b == decode_layer for request b: batching
    amortizes dispatch, never mixes requests. Rows use different caches,
    contexts (valid lengths), positions, and cache slots."""
    tokens = list(sample_tokens.prompt)
    klen = len(tokens)
    nb = CFG.seq_buckets[1]  # 32: fits klen + 1
    l = CFG.mid_layer

    # Per-request K/V caches from a shared prefill (then perturbed so the
    # two requests genuinely differ).
    x = np.zeros((N, CFG.d_model), np.float32)
    x[:klen] = np.asarray(params["emb"])[tokens]
    mask = np.zeros((N,), np.float32)
    mask[:klen] = 1.0
    pos = np.arange(N, dtype=np.int32)
    _, ks, vs = prefill_front(CFG, False, jnp.asarray(x), jnp.asarray(mask),
                              jnp.asarray(pos), *front_params(params))
    base_k = np.zeros((CFG.n_heads, nb, CFG.d_head), np.float32)
    base_v = np.zeros((CFG.n_heads, nb, CFG.d_head), np.float32)
    base_k[:, :klen] = np.asarray(ks[0])[:, :klen]
    base_v[:, :klen] = np.asarray(vs[0])[:, :klen]

    B = 3  # ragged: one row is batch padding (all-zero mask)
    rng = np.random.default_rng(7)
    k_caches = np.zeros((B, CFG.n_heads, nb, CFG.d_head), np.float32)
    v_caches = np.zeros((B, CFG.n_heads, nb, CFG.d_head), np.float32)
    xs = np.zeros((B, CFG.d_model), np.float32)
    positions = np.zeros((B,), np.int32)
    cur_idx = np.zeros((B,), np.int32)
    masks = np.zeros((B, nb), np.float32)
    # Request 0: full context at slot klen; request 1: shorter (pruned)
    # context at slot klen-3 with a different position phase.
    ctxs = [klen, klen - 3]
    for b, ctx in enumerate(ctxs):
        k_caches[b] = base_k + rng.standard_normal(base_k.shape).astype(np.float32) * 0.01 * b
        v_caches[b] = base_v + rng.standard_normal(base_v.shape).astype(np.float32) * 0.01 * b
        k_caches[b][:, ctx:] = 0.0
        v_caches[b][:, ctx:] = 0.0
        xs[b] = np.asarray(params["emb"])[sample_tokens.answer[b % len(sample_tokens.answer)]]
        positions[b] = klen + b
        cur_idx[b] = ctx
        masks[b, :ctx + 1] = 1.0

    xb, kb, vb, sb = decode_layer_batched(
        CFG, False, jnp.asarray(xs), jnp.asarray(positions), jnp.asarray(cur_idx),
        jnp.asarray(k_caches), jnp.asarray(v_caches), jnp.asarray(masks),
        *layer_params(params, l))
    xb, kb, vb, sb = map(np.asarray, (xb, kb, vb, sb))

    for b in range(len(ctxs)):
        x1, k1, v1, s1 = decode_layer(
            CFG, False, jnp.asarray(xs[b]), jnp.int32(positions[b]),
            jnp.int32(cur_idx[b]), jnp.asarray(k_caches[b]),
            jnp.asarray(v_caches[b]), jnp.asarray(masks[b]),
            *layer_params(params, l))
        np.testing.assert_allclose(xb[b], np.asarray(x1), atol=2e-4, rtol=2e-4)
        np.testing.assert_allclose(kb[b], np.asarray(k1), atol=2e-4, rtol=2e-4)
        np.testing.assert_allclose(vb[b], np.asarray(v1), atol=2e-4, rtol=2e-4)
        np.testing.assert_allclose(sb[b], np.asarray(s1), atol=2e-4, rtol=2e-4)

    # The padding row (zero x, zero mask) stays exactly zero — a partially
    # filled batch bucket cannot contaminate anything downstream.
    assert (xb[2] == 0.0).all()
    assert (sb[2] == 0.0).all()


def test_pruned_equals_masked(params, sample_tokens):
    """Gather-compaction with original positions == zero-masking the same
    rows: the kept tokens' hidden states must agree."""
    tokens = sample_tokens.prompt
    klen = len(tokens)
    x = np.zeros((N, CFG.d_model), np.float32)
    x[:klen] = np.asarray(params["emb"])[tokens]
    mask = np.zeros((N,), np.float32)
    mask[:klen] = 1.0
    pos = np.arange(N, dtype=np.int32)
    h, _, _ = prefill_front(CFG, False, jnp.asarray(x), jnp.asarray(mask),
                            jnp.asarray(pos), *front_params(params))
    h = np.asarray(h)

    # Keep a scattered subset that includes the question tail + BOS.
    keep = [0, 2, 3, 7, 9] + list(range(klen - 6, klen))
    keep = sorted(set(keep))
    l = CFG.mid_layer

    # (a) masked execution at the original bucket.
    m2 = np.zeros((N,), np.float32)
    m2[keep] = 1.0
    h_masked, _, _, _ = back_layer(CFG, False, jnp.asarray(h), jnp.asarray(m2),
                                   jnp.asarray(pos), jnp.int32(klen - 1),
                                   *layer_params(params, l))
    h_masked = np.asarray(h_masked)

    # (b) compacted execution at a smaller bucket with original positions.
    nb = CFG.seq_buckets[0]  # 16
    assert len(keep) <= nb
    hc = np.zeros((nb, CFG.d_model), np.float32)
    hc[:len(keep)] = h[keep]
    mc = np.zeros((nb,), np.float32)
    mc[:len(keep)] = 1.0
    pc = np.zeros((nb,), np.int32)
    pc[:len(keep)] = keep
    h_compact, _, _, s = back_layer(CFG, False, jnp.asarray(hc), jnp.asarray(mc),
                                    jnp.asarray(pc), jnp.int32(len(keep) - 1),
                                    *layer_params(params, l))
    h_compact = np.asarray(h_compact)

    np.testing.assert_allclose(h_compact[:len(keep)], h_masked[keep],
                               atol=2e-4, rtol=2e-4)


def test_back_layer_importance_properties(params, sample_tokens):
    tokens = sample_tokens.prompt
    klen = len(tokens)
    x = np.zeros((N, CFG.d_model), np.float32)
    x[:klen] = np.asarray(params["emb"])[tokens]
    mask = np.zeros((N,), np.float32)
    mask[:klen] = 1.0
    pos = np.arange(N, dtype=np.int32)
    h, _, _ = prefill_front(CFG, False, jnp.asarray(x), jnp.asarray(mask),
                            jnp.asarray(pos), *front_params(params))
    _, _, _, s = back_layer(CFG, False, h, jnp.asarray(mask), jnp.asarray(pos),
                            jnp.int32(klen - 1), *layer_params(params, CFG.mid_layer))
    s = np.asarray(s)
    assert abs(s.sum() - 1.0) < 1e-4
    assert (s[klen:] == 0).all()
    assert (s >= 0).all()


def test_calib_probe_shapes_and_stochasticity(params, sample_tokens):
    tokens = sample_tokens.prompt
    klen = len(tokens)
    x = np.zeros((N, CFG.d_model), np.float32)
    x[:klen] = np.asarray(params["emb"])[tokens]
    mask = np.zeros((N,), np.float32)
    mask[:klen] = 1.0
    pos = np.arange(N, dtype=np.int32)
    all_params = [params["layers"][k] for k in
                  ("ln1", "wq", "wk", "wv", "wo", "ln2", "wg", "wu", "wd")]
    roll, attn = calib_probe(CFG, jnp.asarray(x), jnp.asarray(mask),
                             jnp.asarray(pos), *all_params)
    roll, attn = np.asarray(roll), np.asarray(attn)
    assert roll.shape == (CFG.n_layers, N, N)
    assert attn.shape == (CFG.n_layers, N, N)
    # Valid rows of both stacks are (approximately) stochastic.
    for l in range(CFG.n_layers):
        rs = roll[l, :klen].sum(axis=1)
        np.testing.assert_allclose(rs, np.ones(klen), atol=1e-3)
    # Rollout concentration on early tokens is a *trained* property, but
    # mass must stay within the valid region even for random weights.
    assert roll[:, :klen, klen:].max() < 1e-6


def _head_slice(w, s, tp):
    """Columns of a QKV projection owned by head-shard ``s`` of ``tp``."""
    dc = CFG.d_model // tp
    return w[:, s * dc:(s + 1) * dc]


def _front_hidden(params, tokens):
    """Post-front hidden states + mask/pos (shared sharding-test setup)."""
    klen = len(tokens)
    x = np.zeros((N, CFG.d_model), np.float32)
    x[:klen] = np.asarray(params["emb"])[tokens]
    mask = np.zeros((N,), np.float32)
    mask[:klen] = 1.0
    pos = np.arange(N, dtype=np.int32)
    h, ks, vs = prefill_front(CFG, False, jnp.asarray(x), jnp.asarray(mask),
                              jnp.asarray(pos), *front_params(params))
    return np.asarray(h), mask, pos, klen, ks, vs


def test_sharded_layer_equals_unsharded(params, sample_tokens):
    """D layer_shard dispatches + head-order concat + layer_tail ==
    back_layer: h', per-head K/V, and the importance row (partials sum to
    the head mean). This is the numerical contract of the device-mesh
    prefill/back path (tp_degree=2)."""
    tp = 2
    h, mask, pos, klen, _, _ = _front_hidden(params, sample_tokens.prompt)
    l = CFG.mid_layer
    lp = layer_params(params, l)
    want_h, want_k, want_v, want_s = back_layer(
        CFG, False, jnp.asarray(h), jnp.asarray(mask), jnp.asarray(pos),
        jnp.int32(klen - 1), *lp)

    attns, kss, vss, sps = [], [], [], []
    for s in range(tp):
        a, k, v, sp = layer_shard(
            CFG, False, jnp.asarray(h), jnp.asarray(mask), jnp.asarray(pos),
            jnp.int32(klen - 1), lp[0],
            _head_slice(lp[1], s, tp), _head_slice(lp[2], s, tp),
            _head_slice(lp[3], s, tp))
        attns.append(np.asarray(a))
        kss.append(np.asarray(k))
        vss.append(np.asarray(v))
        sps.append(np.asarray(sp))
    attn = np.concatenate(attns, axis=1)  # head-order concat -> [n, d]
    got_h = layer_tail(CFG, jnp.asarray(h), jnp.asarray(attn),
                       jnp.asarray(mask), *lp[4:])
    np.testing.assert_allclose(np.asarray(got_h), np.asarray(want_h),
                               atol=2e-4, rtol=2e-4)
    np.testing.assert_allclose(np.concatenate(kss, axis=0),
                               np.asarray(want_k), atol=2e-4, rtol=2e-4)
    np.testing.assert_allclose(np.concatenate(vss, axis=0),
                               np.asarray(want_v), atol=2e-4, rtol=2e-4)
    # Importance partials sum (all-reduce) to the unsharded head mean.
    np.testing.assert_allclose(sps[0] + sps[1], np.asarray(want_s),
                               atol=2e-4, rtol=2e-4)


def test_sharded_decode_equals_single(params, sample_tokens):
    """D decode_shard dispatches over per-shard head caches + decode_tail
    == decode_layer over the full-head cache (tp_degree=2)."""
    tp = 2
    tokens = list(sample_tokens.prompt)
    klen = len(tokens)
    nb = CFG.seq_buckets[1]  # 32: fits klen + 1
    l = CFG.mid_layer
    lp = layer_params(params, l)
    _, _, _, _, ks, vs = _front_hidden(params, tokens)
    k_cache = np.zeros((CFG.n_heads, nb, CFG.d_head), np.float32)
    v_cache = np.zeros((CFG.n_heads, nb, CFG.d_head), np.float32)
    k_cache[:, :klen] = np.asarray(ks[0])[:, :klen]
    v_cache[:, :klen] = np.asarray(vs[0])[:, :klen]
    mask = np.zeros((nb,), np.float32)
    mask[:klen + 1] = 1.0
    xt = np.asarray(params["emb"])[sample_tokens.answer[0]]

    want_x, want_k, want_v, want_s = decode_layer(
        CFG, False, jnp.asarray(xt), jnp.int32(klen), jnp.int32(klen),
        jnp.asarray(k_cache), jnp.asarray(v_cache), jnp.asarray(mask), *lp)

    hs = CFG.n_heads // tp
    attns, kns, vns, sps = [], [], [], []
    for s in range(tp):
        a, kn, vn, sp = decode_shard(
            CFG, False, jnp.asarray(xt), jnp.int32(klen), jnp.int32(klen),
            jnp.asarray(k_cache[s * hs:(s + 1) * hs]),
            jnp.asarray(v_cache[s * hs:(s + 1) * hs]),
            jnp.asarray(mask), lp[0],
            _head_slice(lp[1], s, tp), _head_slice(lp[2], s, tp),
            _head_slice(lp[3], s, tp))
        attns.append(np.asarray(a))
        kns.append(np.asarray(kn))
        vns.append(np.asarray(vn))
        sps.append(np.asarray(sp))
    got_x = decode_tail(CFG, jnp.asarray(xt),
                        jnp.asarray(np.concatenate(attns)), *lp[4:])
    np.testing.assert_allclose(np.asarray(got_x), np.asarray(want_x),
                               atol=2e-4, rtol=2e-4)
    np.testing.assert_allclose(np.concatenate(kns, axis=0),
                               np.asarray(want_k), atol=2e-4, rtol=2e-4)
    np.testing.assert_allclose(np.concatenate(vns, axis=0),
                               np.asarray(want_v), atol=2e-4, rtol=2e-4)
    np.testing.assert_allclose(sps[0] + sps[1], np.asarray(want_s),
                               atol=2e-4, rtol=2e-4)


def test_sharded_batched_decode_equals_batched(params, sample_tokens):
    """decode_shard_batched + decode_tail_batched == decode_layer_batched
    row-for-row, including the all-zero padding row."""
    tp = 2
    tokens = list(sample_tokens.prompt)
    klen = len(tokens)
    nb = CFG.seq_buckets[1]
    l = CFG.mid_layer
    lp = layer_params(params, l)
    _, _, _, _, ks, vs = _front_hidden(params, tokens)
    B = 2  # one live row + one padding row
    k_caches = np.zeros((B, CFG.n_heads, nb, CFG.d_head), np.float32)
    v_caches = np.zeros((B, CFG.n_heads, nb, CFG.d_head), np.float32)
    k_caches[0, :, :klen] = np.asarray(ks[0])[:, :klen]
    v_caches[0, :, :klen] = np.asarray(vs[0])[:, :klen]
    xs = np.zeros((B, CFG.d_model), np.float32)
    xs[0] = np.asarray(params["emb"])[sample_tokens.answer[0]]
    positions = np.array([klen, 0], np.int32)
    cur_idx = np.array([klen, 0], np.int32)
    masks = np.zeros((B, nb), np.float32)
    masks[0, :klen + 1] = 1.0

    want_x, want_k, want_v, want_s = decode_layer_batched(
        CFG, False, jnp.asarray(xs), jnp.asarray(positions),
        jnp.asarray(cur_idx), jnp.asarray(k_caches), jnp.asarray(v_caches),
        jnp.asarray(masks), *lp)

    hs = CFG.n_heads // tp
    attns, kns, vns, sps = [], [], [], []
    for s in range(tp):
        a, kn, vn, sp = decode_shard_batched(
            CFG, False, jnp.asarray(xs), jnp.asarray(positions),
            jnp.asarray(cur_idx),
            jnp.asarray(k_caches[:, s * hs:(s + 1) * hs]),
            jnp.asarray(v_caches[:, s * hs:(s + 1) * hs]),
            jnp.asarray(masks), lp[0],
            _head_slice(lp[1], s, tp), _head_slice(lp[2], s, tp),
            _head_slice(lp[3], s, tp))
        attns.append(np.asarray(a))
        kns.append(np.asarray(kn))
        vns.append(np.asarray(vn))
        sps.append(np.asarray(sp))
    got_x = decode_tail_batched(CFG, jnp.asarray(xs),
                                jnp.asarray(np.concatenate(attns, axis=1)),
                                *lp[4:])
    np.testing.assert_allclose(np.asarray(got_x), np.asarray(want_x),
                               atol=2e-4, rtol=2e-4)
    np.testing.assert_allclose(np.concatenate(kns, axis=1),
                               np.asarray(want_k), atol=2e-4, rtol=2e-4)
    np.testing.assert_allclose(np.concatenate(vns, axis=1),
                               np.asarray(want_v), atol=2e-4, rtol=2e-4)
    np.testing.assert_allclose(sps[0] + sps[1], np.asarray(want_s),
                               atol=2e-4, rtol=2e-4)


def test_sharded_logits_partials_sum_to_head(params):
    """Summing the D logits_shard partials == logits_head (tp_degree=2)."""
    tp = 2
    rng = np.random.default_rng(3)
    x = rng.standard_normal(CFG.d_model).astype(np.float32)
    want = np.asarray(logits_head(CFG, jnp.asarray(x), params["ln_f"],
                                  params["emb"]))
    dc = CFG.d_model // tp
    got = np.zeros_like(want)
    for s in range(tp):
        emb_s = np.asarray(params["emb"])[:, s * dc:(s + 1) * dc]
        got = got + np.asarray(logits_shard(
            CFG, tp, s, jnp.asarray(x), params["ln_f"], jnp.asarray(emb_s)))
    np.testing.assert_allclose(got, want, atol=2e-4, rtol=2e-4)


def test_batched_logits_head_equals_single(params):
    """logits_head_batched row b == logits_head(x[b]); a zero (padding)
    row yields exactly zero logits. Sharded batched partials also sum to
    the same rows."""
    tp = 2
    rng = np.random.default_rng(4)
    B = 3
    xs = rng.standard_normal((B, CFG.d_model)).astype(np.float32)
    xs[B - 1] = 0.0  # batch padding row
    got = np.asarray(logits_head_batched(CFG, jnp.asarray(xs),
                                         params["ln_f"], params["emb"]))
    for b in range(B - 1):
        want = np.asarray(logits_head(CFG, jnp.asarray(xs[b]),
                                      params["ln_f"], params["emb"]))
        np.testing.assert_allclose(got[b], want, atol=2e-4, rtol=2e-4)
    assert (got[B - 1] == 0.0).all()
    dc = CFG.d_model // tp
    summed = np.zeros_like(got)
    for s in range(tp):
        emb_s = np.asarray(params["emb"])[:, s * dc:(s + 1) * dc]
        summed = summed + np.asarray(logits_shard_batched(
            CFG, tp, s, jnp.asarray(xs), params["ln_f"], jnp.asarray(emb_s)))
    np.testing.assert_allclose(summed, got, atol=2e-4, rtol=2e-4)


def test_logits_head_matches_manual(params):
    x = jnp.asarray(np.random.default_rng(0).standard_normal(CFG.d_model, ).astype(np.float32))
    got = np.asarray(logits_head(CFG, x, params["ln_f"], params["emb"]))
    from compile.model import rms_norm
    want = np.asarray(rms_norm(x, params["ln_f"]) @ params["emb"].T)
    np.testing.assert_allclose(got, want, atol=1e-6)
