"""avsynth generator properties + cross-language RNG reference vectors.

The rust implementation (``rust/src/avsynth/``) pins the *same* vectors —
these tests are the python half of the bit-exactness contract.
"""

import pytest

from compile import avsynth, vocab as V
from compile.avsynth import (
    LayoutCfg,
    SALMSIM_LAYOUT,
    VL2SIM_LAYOUT,
    SEG_AUD,
    SEG_CTRL,
    SEG_TEXT,
    SEG_VIS,
    gen_sample,
)
from compile.rng import SplitMix64, derive_seed

BASE_SEED = 1234


# ------------------------------------------------------------------ RNG


def test_splitmix64_reference_vectors():
    """Known-good SplitMix64 outputs (also pinned in rust)."""
    r = SplitMix64(0)
    assert [r.next_u64() for _ in range(4)] == [
        0xE220A8397B1DCDAF,
        0x6E789E6AA1B965F4,
        0x06C45D188009454F,
        0xF88BB8A8724C81EC,
    ]
    r = SplitMix64(0xDEADBEEF)
    assert r.next_u64() == 0x4ADFB90F68C9EB9B


def test_derive_seed_reference():
    assert derive_seed(1234, 3, 42) == 0x9EEB26CDE5FC895C


def test_next_below_reference():
    r = SplitMix64(999)
    assert [r.next_below(16) for _ in range(8)] == [12, 14, 6, 11, 10, 5, 3, 1]


def test_next_f64_range():
    r = SplitMix64(7)
    for _ in range(100):
        x = r.next_f64()
        assert 0.0 <= x < 1.0


# ------------------------------------------------------------- generators


def test_sample_deterministic():
    a = gen_sample(VL2SIM_LAYOUT, "avqa", 17, BASE_SEED)
    b = gen_sample(VL2SIM_LAYOUT, "avqa", 17, BASE_SEED)
    assert a.prompt == b.prompt and a.answer == b.answer


def test_samples_differ_across_indices():
    a = gen_sample(VL2SIM_LAYOUT, "avqa", 0, BASE_SEED)
    b = gen_sample(VL2SIM_LAYOUT, "avqa", 1, BASE_SEED)
    assert a.prompt != b.prompt


def test_prompt_fits_bucket():
    for ds in ("avqa", "musicavqa", "avhbench"):
        for i in range(50):
            s = gen_sample(VL2SIM_LAYOUT, ds, i, BASE_SEED)
            assert len(s.prompt) <= VL2SIM_LAYOUT.prompt_len_max() <= 128
            assert len(s.prompt) + len(s.answer) <= 128


def test_segment_map_consistent():
    s = gen_sample(VL2SIM_LAYOUT, "avhbench", 5, BASE_SEED)
    assert len(s.segments) == len(s.prompt) == len(s.frame_of)
    assert s.segments[0] == SEG_CTRL and s.prompt[0] == V.BOS
    # Sequential layout: all vis tokens precede all audio tokens.
    vis_idx = [i for i, g in enumerate(s.segments) if g == SEG_VIS]
    aud_idx = [i for i, g in enumerate(s.segments) if g == SEG_AUD]
    assert max(vis_idx) < min(aud_idx)
    assert len(vis_idx) == VL2SIM_LAYOUT.vis_tokens()
    assert len(aud_idx) == VL2SIM_LAYOUT.audio_tokens()
    # Text (question) is the suffix.
    text_idx = [i for i, g in enumerate(s.segments) if g == SEG_TEXT]
    assert text_idx == list(range(len(s.prompt) - len(text_idx), len(s.prompt)))


def test_interleaved_layout_alternates_frames():
    s = gen_sample(SALMSIM_LAYOUT, "avqa", 5, BASE_SEED)
    # Each frame's vis block is immediately followed by its audio block.
    f0 = [i for i, f in enumerate(s.frame_of) if f == 0]
    assert len(f0) == SALMSIM_LAYOUT.vis_per_frame + SALMSIM_LAYOUT.aud_per_frame
    assert f0 == list(range(f0[0], f0[-1] + 1))  # contiguous
    segs = [s.segments[i] for i in f0]
    assert segs == [SEG_VIS] * SALMSIM_LAYOUT.vis_per_frame + [SEG_AUD] * SALMSIM_LAYOUT.aud_per_frame


def test_scene_evidence_in_early_frames():
    for i in range(30):
        s = gen_sample(VL2SIM_LAYOUT, "avqa", i, BASE_SEED)
        tok = V.scene_token(s.scene)
        frames_with_evidence = {
            s.frame_of[j] for j, t in enumerate(s.prompt)
            if t == tok and s.segments[j] == SEG_VIS
        }
        assert frames_with_evidence == set(range(avsynth.EVIDENCE_FRAMES))


def test_sound_evidence_in_early_slots():
    for i in range(30):
        s = gen_sample(VL2SIM_LAYOUT, "avqa", i, BASE_SEED)
        tok = V.sound_token(s.sound)
        aud_positions = [j for j, g in enumerate(s.segments) if g == SEG_AUD]
        ev = [k for k, j in enumerate(aud_positions) if s.prompt[j] == tok]
        assert len(ev) == 1 and ev[0] < avsynth.EVIDENCE_AUD_SLOTS


def test_matching_answer_consistent():
    for i in range(60):
        s = gen_sample(VL2SIM_LAYOUT, "avhbench", i, BASE_SEED)
        if s.subtask != "matching":
            continue
        want = V.YES if s.scene == s.sound else V.NO
        assert s.answer[0] == want


def test_hallucination_answer_consistent():
    seen_yes = seen_no = False
    for i in range(120):
        s = gen_sample(VL2SIM_LAYOUT, "avhbench", i, BASE_SEED)
        if s.subtask != "hallucination":
            continue
        probe = s.prompt[-2]  # [SEP, qword, arg, SEP]
        if V.SCENE_BASE <= probe < V.SCENE_BASE + V.NUM_CLASSES:
            present = probe == V.scene_token(s.scene)
        else:
            present = probe == V.sound_token(s.sound)
        assert s.answer[0] == (V.YES if present else V.NO)
        seen_yes |= s.answer[0] == V.YES
        seen_no |= s.answer[0] == V.NO
    assert seen_yes and seen_no


def test_beats_counted_correctly():
    for i in range(60):
        s = gen_sample(VL2SIM_LAYOUT, "musicavqa", i, BASE_SEED)
        if s.subtask != "how_many_beats":
            continue
        n_beats = sum(
            1 for j, t in enumerate(s.prompt)
            if t == V.BEAT and s.segments[j] == SEG_AUD
        )
        assert s.answer[0] == V.digit_token(n_beats)
        assert n_beats == s.beats


def test_captioning_answer_has_scene_and_sound():
    for i in range(60):
        s = gen_sample(VL2SIM_LAYOUT, "avhbench", i, BASE_SEED)
        if s.subtask != "captioning":
            continue
        assert s.answer == [V.scene_token(s.scene), V.sound_token(s.sound), V.EOS]


def test_dataset_streams_disjoint():
    a = gen_sample(VL2SIM_LAYOUT, "avqa", 0, BASE_SEED)
    b = gen_sample(VL2SIM_LAYOUT, "avhbench", 0, BASE_SEED)
    assert a.prompt != b.prompt


def test_answers_end_with_eos():
    for ds in ("avqa", "musicavqa", "avhbench"):
        for i in range(20):
            s = gen_sample(VL2SIM_LAYOUT, ds, i, BASE_SEED)
            assert s.answer[-1] == V.EOS
            assert 2 <= len(s.answer) <= 4


# Reference prompt prefix pinned for rust cross-checks (computed from this
# implementation once; both sides must reproduce it).
def test_pinned_sample_prefix():
    s = gen_sample(VL2SIM_LAYOUT, "avqa", 0, BASE_SEED)
    assert s.prompt[0] == V.BOS
    # Pin the whole sample via a cheap structural hash both languages can compute.
    h = 0
    for t in s.prompt:
        h = (h * 31 + t) % (1 << 32)
    # Recorded from the python implementation; rust must match.
    import json, os
    ref_path = os.path.join(os.path.dirname(__file__), "..", "..", "testdata")
    os.makedirs(ref_path, exist_ok=True)
    vec_file = os.path.join(ref_path, "avsynth_vectors.json")
    vectors = []
    for ds in ("avqa", "musicavqa", "avhbench"):
        for idx in (0, 1, 7):
            for name, cfg in (("vl2sim", VL2SIM_LAYOUT), ("salmsim", SALMSIM_LAYOUT)):
                smp = gen_sample(cfg, ds, idx, BASE_SEED)
                hh = 0
                for t in smp.prompt + smp.answer:
                    hh = (hh * 31 + t) % (1 << 32)
                vectors.append({
                    "layout": name, "dataset": ds, "index": idx,
                    "prompt_len": len(smp.prompt), "hash": hh,
                    "subtask": smp.subtask,
                    "answer": smp.answer,
                })
    with open(vec_file, "w") as f:
        json.dump(vectors, f, indent=1)
    assert len(vectors) == 18
