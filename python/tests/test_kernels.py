"""Kernel-vs-oracle correctness: the core L1 signal.

Every Pallas kernel is compared against its pure-jnp reference from
``compile.kernels.ref`` over a hypothesis-driven sweep of shapes, block
sizes, and mask patterns, plus deterministic edge cases (full mask, empty
mask, single block, non-square blocks).
"""

import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import (
    decode_attention,
    flash_attention,
    importance_scores,
    rollout_step,
    ref,
)

# interpret-mode pallas is slow; keep hypothesis example counts modest.
EXAMPLES = 12
DEADLINE = None


def make_qkv(rng, h, n, dh):
    q = rng.standard_normal((h, n, dh), dtype=np.float32)
    k = rng.standard_normal((h, n, dh), dtype=np.float32)
    v = rng.standard_normal((h, n, dh), dtype=np.float32)
    return jnp.asarray(q), jnp.asarray(k), jnp.asarray(v)


def prefix_mask(n, valid):
    return jnp.asarray((np.arange(n) < valid).astype(np.float32))


# ---------------------------------------------------------------- attention


@settings(max_examples=EXAMPLES, deadline=DEADLINE)
@given(
    h=st.sampled_from([1, 2, 4]),
    n=st.sampled_from([16, 32, 64, 128]),
    dh=st.sampled_from([8, 16, 32]),
    valid_frac=st.floats(0.2, 1.0),
    causal=st.booleans(),
    seed=st.integers(0, 2**31 - 1),
)
def test_attention_matches_ref(h, n, dh, valid_frac, causal, seed):
    rng = np.random.default_rng(seed)
    q, k, v = make_qkv(rng, h, n, dh)
    mask = prefix_mask(n, max(1, int(n * valid_frac)))
    bq = bk = min(n, 32)
    got = flash_attention(q, k, v, mask, causal=causal, block_q=bq, block_k=bk)
    want = ref.ref_attention(q, k, v, mask, causal=causal)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=2e-5, rtol=2e-5)


@pytest.mark.parametrize("bq,bk", [(16, 64), (64, 16), (32, 32), (64, 64)])
def test_attention_block_shapes(bq, bk):
    rng = np.random.default_rng(7)
    q, k, v = make_qkv(rng, 2, 64, 16)
    mask = prefix_mask(64, 64)
    got = flash_attention(q, k, v, mask, causal=True, block_q=bq, block_k=bk)
    want = ref.ref_attention(q, k, v, mask, causal=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=2e-5, rtol=2e-5)


def test_attention_scattered_mask():
    """Masks need not be prefixes — compaction leaves arbitrary hole patterns."""
    rng = np.random.default_rng(11)
    q, k, v = make_qkv(rng, 2, 32, 8)
    m = (rng.random(32) > 0.4).astype(np.float32)
    m[0] = 1.0  # keep at least the first key so row 0 is attendable
    mask = jnp.asarray(m)
    got = flash_attention(q, k, v, mask, causal=True, block_q=16, block_k=16)
    want = ref.ref_attention(q, k, v, mask, causal=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=2e-5, rtol=2e-5)


def test_attention_single_block():
    rng = np.random.default_rng(3)
    q, k, v = make_qkv(rng, 1, 16, 8)
    mask = prefix_mask(16, 16)
    got = flash_attention(q, k, v, mask, causal=False, block_q=16, block_k=16)
    want = ref.ref_attention(q, k, v, mask, causal=False)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=2e-5, rtol=2e-5)


def test_attention_fully_masked_rows_finite():
    """Rows whose keys are all masked must produce finite output, not NaN."""
    rng = np.random.default_rng(5)
    q, k, v = make_qkv(rng, 2, 32, 8)
    mask = jnp.zeros((32,), jnp.float32)
    got = flash_attention(q, k, v, mask, causal=True, block_q=16, block_k=16)
    assert np.isfinite(np.asarray(got)).all()


def test_attention_causality():
    """Future keys must not influence earlier queries."""
    rng = np.random.default_rng(9)
    q, k, v = make_qkv(rng, 2, 32, 8)
    mask = prefix_mask(32, 32)
    base = np.asarray(flash_attention(q, k, v, mask, block_q=16, block_k=16))
    # Perturb the last key/value; only the last row may change.
    k2 = k.at[:, -1, :].add(3.0)
    v2 = v.at[:, -1, :].add(3.0)
    pert = np.asarray(flash_attention(q, k2, v2, mask, block_q=16, block_k=16))
    np.testing.assert_allclose(base[:, :-1, :], pert[:, :-1, :], atol=1e-6)
    assert np.abs(base[:, -1, :] - pert[:, -1, :]).max() > 1e-4


# --------------------------------------------------------------- importance


@settings(max_examples=EXAMPLES, deadline=DEADLINE)
@given(
    h=st.sampled_from([1, 2, 4]),
    n=st.sampled_from([16, 32, 64, 128]),
    dh=st.sampled_from([8, 16, 32]),
    valid_frac=st.floats(0.2, 1.0),
    seed=st.integers(0, 2**31 - 1),
)
def test_importance_matches_ref(h, n, dh, valid_frac, seed):
    rng = np.random.default_rng(seed)
    _, k, _ = make_qkv(rng, h, n, dh)
    q_last = jnp.asarray(rng.standard_normal((h, dh), dtype=np.float32))
    mask = prefix_mask(n, max(1, int(n * valid_frac)))
    got = importance_scores(q_last, k, mask, block_k=min(n, 32))
    want = ref.ref_importance(q_last, k, mask)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-6, rtol=1e-5)


def test_importance_sums_to_one():
    """Scores are a probability distribution over valid keys."""
    rng = np.random.default_rng(1)
    _, k, _ = make_qkv(rng, 4, 64, 16)
    q_last = jnp.asarray(rng.standard_normal((4, 16), dtype=np.float32))
    mask = prefix_mask(64, 40)
    s = np.asarray(importance_scores(q_last, k, mask, block_k=32))
    assert abs(s.sum() - 1.0) < 1e-5
    assert (s[40:] == 0).all()
    assert (s >= 0).all()


def test_importance_zero_on_padding():
    rng = np.random.default_rng(2)
    _, k, _ = make_qkv(rng, 2, 32, 8)
    q_last = jnp.asarray(rng.standard_normal((2, 8), dtype=np.float32))
    mask = prefix_mask(32, 7)
    s = np.asarray(importance_scores(q_last, k, mask, block_k=16))
    assert (s[7:] == 0).all()


# ------------------------------------------------------------------- decode


@settings(max_examples=EXAMPLES, deadline=DEADLINE)
@given(
    h=st.sampled_from([1, 2, 4]),
    n=st.sampled_from([16, 32, 64]),
    dh=st.sampled_from([8, 16, 32]),
    valid_frac=st.floats(0.2, 1.0),
    seed=st.integers(0, 2**31 - 1),
)
def test_decode_matches_ref(h, n, dh, valid_frac, seed):
    rng = np.random.default_rng(seed)
    _, k, v = make_qkv(rng, h, n, dh)
    q1 = jnp.asarray(rng.standard_normal((h, dh), dtype=np.float32))
    mask = prefix_mask(n, max(1, int(n * valid_frac)))
    got_o, got_s = decode_attention(q1, k, v, mask, block_k=min(n, 16))
    want_o, want_s = ref.ref_decode_attention(q1, k, v, mask)
    np.testing.assert_allclose(np.asarray(got_o), np.asarray(want_o), atol=2e-5, rtol=2e-5)
    np.testing.assert_allclose(np.asarray(got_s), np.asarray(want_s), atol=1e-6, rtol=1e-5)


def test_decode_importance_consistent_with_importance_kernel():
    """The decode kernel's score row equals the standalone importance kernel."""
    rng = np.random.default_rng(4)
    _, k, v = make_qkv(rng, 4, 64, 16)
    q1 = jnp.asarray(rng.standard_normal((4, 16), dtype=np.float32))
    mask = prefix_mask(64, 64)
    _, s_dec = decode_attention(q1, k, v, mask, block_k=32)
    s_imp = importance_scores(q1, k, mask, block_k=32)
    np.testing.assert_allclose(np.asarray(s_dec), np.asarray(s_imp), atol=1e-6)


# ------------------------------------------------------------------ rollout


@settings(max_examples=EXAMPLES, deadline=DEADLINE)
@given(
    n=st.sampled_from([16, 32, 64, 128]),
    alpha=st.floats(0.0, 1.0),
    seed=st.integers(0, 2**31 - 1),
)
def test_rollout_matches_ref(n, alpha, seed):
    rng = np.random.default_rng(seed)
    a = jnp.asarray(rng.random((n, n), dtype=np.float32))
    r = jnp.asarray(rng.random((n, n), dtype=np.float32))
    got = rollout_step(a, r, alpha, block=min(n, 32))
    want = ref.ref_rollout_step(a, r, alpha)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-4, rtol=1e-4)


def test_rollout_alpha_zero_is_identity():
    """alpha=0 keeps R unchanged (pure residual)."""
    rng = np.random.default_rng(6)
    a = jnp.asarray(rng.random((32, 32), dtype=np.float32))
    r = jnp.asarray(rng.random((32, 32), dtype=np.float32))
    got = np.asarray(rollout_step(a, r, 0.0, block=16))
    np.testing.assert_allclose(got, np.asarray(r), atol=1e-6)


def test_rollout_preserves_row_stochasticity():
    """Row-stochastic A and R give a row-stochastic R' for any alpha."""
    rng = np.random.default_rng(8)
    a = rng.random((32, 32)).astype(np.float32)
    a /= a.sum(axis=1, keepdims=True)
    r = np.eye(32, dtype=np.float32)
    got = np.asarray(rollout_step(jnp.asarray(a), jnp.asarray(r), 0.7, block=16))
    np.testing.assert_allclose(got.sum(axis=1), np.ones(32), atol=1e-5)
