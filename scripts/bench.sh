#!/usr/bin/env sh
# Run every serve_load benchmark phase and rewrite the BENCH_*.json
# files at the repository root with measured=true results.
#
# Phases (one process, sequential):
#   1-2  mixed short/long HTTP load, single replica vs pool of 4  -> BENCH_serving.json
#   3    repeated-prefix workload (AV-prefix cache)               -> BENCH_prefix.json
#   4    saturated decode, batched vs single-step                 -> BENCH_batch.json
#   5    mixed quality/aggressive profiles over /v2/generate      -> BENCH_policy.json
#   6    chaos soak under a seeded FaultPlan                      -> BENCH_chaos.json
#   7    mesh worker-queue overhead + pipelined vs sequential     -> BENCH_mesh.json
#   8    tiered KV spill, working set 4x device budget            -> BENCH_tiered.json
#   9    streamed (SSE) vs buffered delivery, TTFT + KV high-water -> BENCH_streaming.json
#
# Usage: scripts/bench.sh [model] [n_requests]

set -eu

cd "$(dirname "$0")/.."

if ! command -v cargo >/dev/null 2>&1; then
    echo "scripts/bench.sh: no Rust toolchain on this machine (cargo not found)."
    echo "Nothing was run; the committed BENCH_*.json placeholders are unchanged."
    echo "Install a Rust toolchain (and build artifacts: python/compile/aot.py),"
    echo "then re-run this script to produce measured results."
    exit 0
fi

MODEL="${1:-vl2sim}"
N="${2:-48}"

if [ ! -d "rust/artifacts/$MODEL" ]; then
    echo "scripts/bench.sh: no AOT artifacts for model '$MODEL' (rust/artifacts/$MODEL missing)."
    echo "Build them first (python/compile/aot.py), then re-run."
    exit 1
fi

echo "running serve_load phases 1-9 (model=$MODEL, n=$N)..."
cargo run --release --example serve_load "$MODEL" "$N"
echo
echo "rewrote: BENCH_serving.json BENCH_prefix.json BENCH_batch.json" \
     "BENCH_policy.json BENCH_chaos.json BENCH_mesh.json BENCH_tiered.json" \
     "BENCH_streaming.json (measured=true)"
